"""Shared benchmark utilities: timing + a cached small trained model used by
the accuracy-reproduction benchmarks (Tables 2/3/5, Fig. 6).

No ImageNet/CIFAR is available offline (see DESIGN.md §6), so accuracy
benchmarks reproduce the paper's *orderings and deltas* on a deterministic
synthetic next-token task that a small LM learns well — the quantization
math (what the paper's tables measure) is exercised identically.
"""
from __future__ import annotations

import os
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.configs.base import ArchConfig, QuantPolicy
from repro.core.swis import QuantConfig
from repro.data import SyntheticPipeline
from repro.models.model import Model
from repro.train.loop import Trainer

BENCH_DIR = os.environ.get("REPRO_BENCH_DIR", "results/bench")


def time_us(fn: Callable, *args, n: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        r = fn(*args)
        jax.block_until_ready(r) if hasattr(r, "block_until_ready") else None
    t0 = time.perf_counter()
    for _ in range(n):
        r = fn(*args)
        if hasattr(r, "block_until_ready"):
            r.block_until_ready()
        else:
            jax.tree.map(lambda x: getattr(x, "block_until_ready", lambda: x)(),
                         r)
    return (time.perf_counter() - t0) / n * 1e6


_MODEL_CACHE: dict = {}


def trained_smoke_model(steps: int = 400, seq: int = 64, batch: int = 16):
    """Train (or load) the benchmark model: smollm-smoke on the synthetic
    affine-recurrence LM task. Returns (cfg, params, eval_fn)."""
    key = (steps, seq, batch)
    if key in _MODEL_CACHE:
        return _MODEL_CACHE[key]
    cfg = C.get_smoke("smollm-135m").replace(compute_dtype="float32")
    workdir = os.path.join(BENCH_DIR, f"model_{steps}_{seq}_{batch}")
    tr = Trainer(cfg, seq_len=seq, global_batch=batch, workdir=workdir,
                 total_steps=steps, ckpt_every=steps, warmup=20,
                 peak_lr=5e-3)
    out = tr.run(steps)
    params = out["state"].params

    model = Model(cfg)
    pipe = SyntheticPipeline(cfg, seq, batch, seed=0)

    def eval_acc(eval_cfg: ArchConfig, eval_params=None, n_batches: int = 4
                 ) -> float:
        m = Model(eval_cfg)
        p = eval_params if eval_params is not None else params
        accs = []
        for i in range(n_batches):
            b = jax.tree.map(jnp.asarray, pipe.batch_at(100000 + i))
            _, metrics = m.loss(p, b)
            accs.append(float(metrics["accuracy"]))
        return float(np.mean(accs))

    _MODEL_CACHE[key] = (cfg, params, eval_acc)
    return _MODEL_CACHE[key]


def quant_policy(method: str, n_shifts: float, *, ds: bool = False,
                 schedule: bool = True, group: int = 4,
                 act_shifts: int = 0) -> QuantPolicy:
    if method == "act_trunc":
        return QuantPolicy(cfg=QuantConfig(method="none"), mode="off",
                           act_shifts=act_shifts or int(n_shifts))
    return QuantPolicy(
        cfg=QuantConfig(method=method, n_shifts=n_shifts, group_size=group,
                        double_shift=ds, schedule=schedule),
        mode="ptq")
