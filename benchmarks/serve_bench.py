"""Continuous-batching serve benchmark: tokens/sec at mixed prompt lengths.

Workloads model the traffic shapes a serving fleet actually sees:

  uniform        every request arrives up front with the same prompt length
                 (the static engine's best case — measures pure decode rate)
  mixed          prompt lengths spread 4-32 tokens, token budgets spread too,
                 arrivals staggered so slots are recycled mid-flight (the
                 case that requires continuous batching)
  shared_prefix  N requests over K distinct system prompts (each request =
                 one of K long shared prefixes + a short unique tail) —
                 the shape the radix prefix cache exists for; the report
                 adds hit rate and prefill tokens avoided

Run:  PYTHONPATH=src python benchmarks/serve_bench.py [--packed] \
          [--arch smollm-135m --n-slots 4 --requests 12] \
          [--no-prefix-cache] [--block-size 8]

Prints one JSON line per (workload, engine-config) with wall seconds and
generated tokens/sec (plus prefix_stats fields when the cache is on).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

import repro.configs as C
from repro.core.swis import QuantConfig
from repro.models import params as pp
from repro.models.model import Model
from repro.serve import ContinuousBatchingEngine

MAX_LEN = 64


def _requests_uniform(rng, cfg, n):
    return [(rng.integers(0, cfg.vocab, (8,)).astype(np.int32), 16, 0)
            for _ in range(n)]


def _requests_mixed(rng, cfg, n):
    out = []
    for i in range(n):
        s0 = int(rng.integers(4, 33))
        n_tok = int(rng.integers(8, MAX_LEN - s0 + 1))
        arrive = int(rng.integers(0, 12)) if i >= n // 3 else 0
        out.append((rng.integers(0, cfg.vocab, (s0,)).astype(np.int32),
                    n_tok, arrive))
    return out


def _requests_shared_prefix(rng, cfg, n, n_sys=3, sys_len=24):
    sys_prompts = [rng.integers(0, cfg.vocab, (sys_len,)).astype(np.int32)
                   for _ in range(n_sys)]
    out = []
    for i in range(n):
        tail = rng.integers(0, cfg.vocab,
                            (int(rng.integers(3, 9)),)).astype(np.int32)
        prompt = np.concatenate([sys_prompts[i % n_sys], tail])
        arrive = int(rng.integers(0, 10)) if i >= n_sys else 0
        out.append((prompt, int(rng.integers(8, 17)), arrive))
    return out


WORKLOADS = {"uniform": _requests_uniform, "mixed": _requests_mixed,
             "shared_prefix": _requests_shared_prefix}


def run_workload(name, cfg, params, *, n_slots, requests, packed, qcfg,
                 prefix_cache=True, block_size=8):
    rng = np.random.default_rng(0)
    reqs = WORKLOADS[name](rng, cfg, requests)
    total_tokens = sum(n for _, n, _ in reqs)

    def one_pass():
        eng = ContinuousBatchingEngine(cfg, params, max_len=MAX_LEN,
                                       n_slots=n_slots, packed=packed,
                                       quant_cfg=qcfg,
                                       prefix_cache=prefix_cache,
                                       block_size=block_size)
        pending = sorted(range(len(reqs)), key=lambda i: reqs[i][2])
        t0 = time.perf_counter()
        step = 0
        done = 0
        while done < len(reqs):
            while pending and reqs[pending[0]][2] <= step:
                i = pending.pop(0)
                eng.submit(reqs[i][0], reqs[i][1])
            done += len(eng.step())
            step += 1
        return time.perf_counter() - t0, eng

    one_pass()  # warmup pass: all prefill/decode shapes compile here
    dt, eng = one_pass()
    rep = {"workload": name, "engine": "continuous", "packed": packed,
           "prefix_cache": eng.prefix_cache is not None,
           "requests": len(reqs), "n_slots": n_slots,
           "gen_tokens": total_tokens, "wall_s": round(dt, 3),
           "tok_per_s": round(total_tokens / dt, 1)}
    stats = eng.prefix_stats()
    prompt_tokens = sum(len(p) for p, _, _ in reqs)
    rep["prompt_tokens"] = prompt_tokens
    rep["prefill_tokens"] = stats["prefill_tokens"]
    if stats["enabled"]:
        rep["hit_rate"] = round(stats["hit_rate"], 3)
        rep["prefill_tokens_saved"] = stats["saved_tokens"]
        rep["evictions"] = stats["evictions"]
    return rep


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--packed", action="store_true")
    ap.add_argument("--n-shifts", type=int, default=4)
    ap.add_argument("--workloads", default="uniform,mixed,shared_prefix")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="contiguous per-slot KV (no block sharing)")
    ap.add_argument("--block-size", type=int, default=8)
    args = ap.parse_args()

    cfg = C.get_smoke(args.arch).replace(compute_dtype="float32")
    params = pp.init_params(Model(cfg).build(), jax.random.key(0))
    qcfg = QuantConfig(method="swis", n_shifts=args.n_shifts, group_size=4)

    names = [n.strip() for n in args.workloads.split(",")]
    unknown = [n for n in names if n not in WORKLOADS]
    if unknown:
        ap.error(f"unknown workload(s) {unknown}; "
                 f"choose from {sorted(WORKLOADS)}")
    for name in names:
        rep = run_workload(name, cfg, params, n_slots=args.n_slots,
                           requests=args.requests, packed=args.packed,
                           qcfg=qcfg, prefix_cache=not args.no_prefix_cache,
                           block_size=args.block_size)
        print(json.dumps(rep))


if __name__ == "__main__":
    main()
