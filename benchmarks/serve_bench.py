"""Continuous-batching serve benchmark: tokens/sec, per-step latency, TTFT.

Workloads model the traffic shapes a serving fleet actually sees:

  uniform        every request arrives up front with the same prompt length
                 (the static engine's best case — measures pure decode rate)
  mixed          prompt lengths spread 4-32 tokens, token budgets spread too,
                 arrivals staggered so slots are recycled mid-flight (the
                 case that requires continuous batching)
  shared_prefix  N requests over K distinct system prompts (each request =
                 one of K long shared prefixes + a short unique tail) —
                 the shape the radix prefix cache exists for; the report
                 adds hit rate and prefill tokens avoided
  long_prompt    a few very long prompts land while short requests decode —
                 the head-of-line-blocking shape chunked prefill exists
                 for; run twice (chunked + unchunked) and report the p95
                 per-step latency each way plus the speedup
  decode_heavy   many slots decoding against long committed contexts with
                 almost no prefill — the shape where the reference decode
                 path's per-step gathered K/V copy dominates; run twice
                 (fused paged kernel + gather reference) and report p50/p95
                 step latency each way plus the per-step gathered bytes
                 each path materializes
  mixed_load     chunked long prompts landing while a deep decode
                 population keeps generating — every chunk-servicing step
                 pays prefill AND decode; run twice (fused mixed step +
                 separate chunk-then-decode) and report p50/p95 step
                 latency each way plus model dispatches per pass (the
                 fused step's one-launch win)
  spec_decode    deep decode budgets over short prompts — the shape
                 self-speculative decode exists for; run twice (spec on
                 with a truncated bit-slice draft + plain decode on the
                 same traffic, both packed) and report the accept rate,
                 tokens per spec step, dispatch counts, and the
                 PER-TOKEN p95 step-latency speedup (a spec step emits
                 several tokens, so raw per-step latency is the wrong
                 unit; on CPU the k+1 launches per step usually cost
                 more wall time than they save — the accept rate and
                 dispatch accounting are the signal, the speedup gate is
                 a floor against collapse, not a win claim)

Run:  PYTHONPATH=src python benchmarks/serve_bench.py [--packed] \
          [--arch smollm-135m --n-slots 4 --requests 12] \
          [--no-prefix-cache] [--block-size 8] [--prefill-chunk 32] \
          [--json-out BENCH_serve.json] \
          [--check-baseline benchmarks/baseline.json] [--update-baseline]

Prints one JSON line per (workload, engine-config) with wall seconds,
generated tokens/sec, p50/p95 per-step wall time, and time-to-first-token
percentiles (plus prefix_stats fields when the cache is on).

Per-step percentiles come from the engine's own ``step.total_s`` phase
histogram and TTFT / TPOT from the request-lifecycle trace
(``engine.tracer.summary()``) — the bench no longer keeps hand-rolled
``perf_counter`` bookkeeping, so its numbers are definitionally the same
ones ``engine.metrics()`` reports in production.

``--json-out`` additionally writes one JSON object per workload (a dict
keyed by workload name) — the CI perf trajectory artifact. With
``--check-baseline`` the run exits non-zero if tokens/sec or p95 step
latency regresses more than ``--baseline-tolerance`` (default 25%) vs the
committed baseline; ``--update-baseline`` rewrites that baseline from the
current run (gated fields with headroom, plus per-phase p95s and cost
counters for ``check_bench.py --baseline`` regression *attribution*).
``--artifacts-dir DIR`` exports, per workload variant, the last measured
pass's trace (``trace_<tag>.jsonl``), Chrome trace-event JSON
(``chrome_trace_<tag>.json`` — load in Perfetto), and full
``engine.metrics()`` snapshot (``metrics_<tag>.json``) — the CI bench job
uploads these, and ``check_bench.py --require-metrics DIR`` validates
them (including the cost counters and the Chrome trace schema).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import numpy as np

import repro.configs as C
from repro.core.swis import QuantConfig
from repro.models import params as pp
from repro.models.model import Model
from repro.serve import ContinuousBatchingEngine, EngineConfig, SamplingParams

MAX_LEN = 64
LONG_MAX_LEN = 512
LONG_PREFILL_CHUNK = 32
LONG_PROMPT_LEN = 14 * LONG_PREFILL_CHUNK  # 448 tokens, 14 chunks
HEAVY_MAX_LEN = 192
HEAVY_PREFIX_LEN = 120  # 15 blocks of committed context per request
HEAVY_N_SLOTS = 8
MIXED_MAX_LEN = 160
MIXED_PREFILL_CHUNK = 16
MIXED_PROMPT_LEN = 6 * MIXED_PREFILL_CHUNK  # 96 tokens, 6 chunks
MIXED_N_SLOTS = 6


def _requests_uniform(rng, cfg, n):
    return [(rng.integers(0, cfg.vocab, (8,)).astype(np.int32), 16, 0)
            for _ in range(n)]


def _requests_mixed(rng, cfg, n):
    out = []
    for i in range(n):
        s0 = int(rng.integers(4, 33))
        n_tok = int(rng.integers(8, MAX_LEN - s0 + 1))
        arrive = int(rng.integers(0, 12)) if i >= n // 3 else 0
        out.append((rng.integers(0, cfg.vocab, (s0,)).astype(np.int32),
                    n_tok, arrive))
    return out


def _requests_shared_prefix(rng, cfg, n, n_sys=3, sys_len=24):
    sys_prompts = [rng.integers(0, cfg.vocab, (sys_len,)).astype(np.int32)
                   for _ in range(n_sys)]
    out = []
    for i in range(n):
        tail = rng.integers(0, cfg.vocab,
                            (int(rng.integers(3, 9)),)).astype(np.int32)
        prompt = np.concatenate([sys_prompts[i % n_sys], tail])
        arrive = int(rng.integers(0, 10)) if i >= n_sys else 0
        out.append((prompt, int(rng.integers(8, 17)), arrive))
    return out


def _requests_long_prompt(rng, cfg, n):
    """Prompts of 14x the chunk size arrive while short requests decode:
    unchunked, each long prefill stalls every decoding slot for one
    monolithic step; chunked, the same work lands 32 tokens at a time."""
    n_long = max(1, min(4, n // 2))
    out = []
    for i in range(n_long):
        prompt = rng.integers(0, cfg.vocab,
                              (LONG_PROMPT_LEN,)).astype(np.int32)
        out.append((prompt, 12, i * 4))
    for i in range(max(0, n - n_long)):
        prompt = rng.integers(0, cfg.vocab, (8,)).astype(np.int32)
        out.append((prompt, 12, i * 2))
    return out


def _requests_decode_heavy(rng, cfg, n):
    """Every slot decodes a long tail against a long committed context:
    one shared long prefix (cached after the first admission) + a few
    unique tokens, then a deep decode. Prefill is a sliver of the work;
    the steady state is all slots deep in paged decode — the shape where
    the gather path re-materializes the whole arena view every step."""
    prefix = rng.integers(0, cfg.vocab,
                          (HEAVY_PREFIX_LEN,)).astype(np.int32)
    out = []
    for i in range(n):
        tail = rng.integers(0, cfg.vocab,
                            (int(rng.integers(2, 6)),)).astype(np.int32)
        out.append((np.concatenate([prefix, tail]), 48, 0))
    return out


def _requests_spec_decode(rng, cfg, n):
    """Short prompts, deep decode budgets, a couple of late arrivals:
    almost every step is a pure-decode step, which is exactly where the
    speculative draft/verify rounds replace plain one-token steps."""
    out = []
    for i in range(n):
        prompt = rng.integers(0, cfg.vocab,
                              (int(rng.integers(6, 14)),)).astype(np.int32)
        arrive = int(rng.integers(0, 8)) if i >= n - n // 4 else 0
        out.append((prompt, 32, arrive))
    return out


def _requests_mixed_load(rng, cfg, n):
    """Deep decoders occupy most slots from step 0 while chunked long
    prompts keep arriving: every chunk-servicing step pays one chunk of
    prefill AND a full decode batch — separate, that is two sequenced
    launches per step; fused, one mixed dispatch."""
    n_long = max(1, n // 3)
    out = []
    for i in range(max(0, n - n_long)):
        prompt = rng.integers(0, cfg.vocab, (6,)).astype(np.int32)
        out.append((prompt, 40, 0))
    for i in range(n_long):
        prompt = rng.integers(0, cfg.vocab,
                              (MIXED_PROMPT_LEN,)).astype(np.int32)
        out.append((prompt, 8, 2 + i * 3))
    return out


WORKLOADS = {"uniform": _requests_uniform, "mixed": _requests_mixed,
             "shared_prefix": _requests_shared_prefix,
             "long_prompt": _requests_long_prompt,
             "decode_heavy": _requests_decode_heavy,
             "mixed_load": _requests_mixed_load,
             "spec_decode": _requests_spec_decode}
WORKLOAD_MAX_LEN = {"long_prompt": LONG_MAX_LEN,
                    "decode_heavy": HEAVY_MAX_LEN,
                    "mixed_load": MIXED_MAX_LEN}
WORKLOAD_N_SLOTS = {"decode_heavy": HEAVY_N_SLOTS,
                    "mixed_load": MIXED_N_SLOTS}


def _decode_gathered_bytes(eng, cfg):
    """Peak bytes of gathered K/V one decode step materializes, summed over
    layers. The reference path rebuilds each slot's contiguous arena view
    (n_blocks_per_slot * block_size positions); the fused XLA fallback
    touches one block_size slab per scan step; the Pallas kernel indexes
    the arena in place and gathers nothing."""
    kv = 2 * eng.n_slots * cfg.n_kv_heads * cfg.head_dim * cfg.n_layers
    itemsize = np.dtype(eng.cache.dtype).itemsize
    if eng.paged_impl is None:
        return kv * eng.cache.eff_len * itemsize
    if eng.paged_impl == "xla":
        return kv * eng.cache.block_size * itemsize
    return 0  # pallas: in-kernel indirection, no gathered copy


def run_workload(name, cfg, params, *, n_slots, requests, packed, qcfg,
                 prefix_cache=True, block_size=8, prefill_chunk=None,
                 max_len=None, passes=3, use_paged_kernel=False,
                 fused_step=False, spec_decode=False, spec_k=3,
                 draft_slices=None, artifacts_dir=None, artifact_tag=None):
    max_len = max_len or WORKLOAD_MAX_LEN.get(name, MAX_LEN)
    n_slots = WORKLOAD_N_SLOTS.get(name, n_slots)
    if not prefix_cache:
        prefill_chunk = None  # chunking needs block mode; degrade, not crash
    rng = np.random.default_rng(0)
    reqs = WORKLOADS[name](rng, cfg, requests)
    total_tokens = sum(n for _, n, _ in reqs)

    eng = ContinuousBatchingEngine(cfg, params, config=EngineConfig(
        max_len=max_len, n_slots=n_slots, packed=packed, quant_cfg=qcfg,
        prefix_cache=prefix_cache, block_size=block_size,
        prefill_chunk=prefill_chunk, use_paged_kernel=use_paged_kernel,
        fused_step=fused_step, spec_decode=spec_decode, spec_k=spec_k,
        draft_slices=draft_slices))

    def one_pass():
        """Drive the traffic; all timing observability comes from the
        engine's metrics/trace layer, not bench-side bookkeeping."""
        pending = sorted(range(len(reqs)), key=lambda i: reqs[i][2])
        t0 = time.perf_counter()
        step = 0
        done = 0
        while done < len(reqs):
            while pending and reqs[pending[0]][2] <= step:
                i = pending.pop(0)
                eng.submit(reqs[i][0],
                           SamplingParams(max_tokens=reqs[i][1]))
            done += len(eng.step())
            step += 1
        return time.perf_counter() - t0

    def pass_report(dt):
        hist = eng.metrics_registry.histogram("step.total_s")
        hs = hist.summary()
        ts = eng.tracer.summary()
        snap = eng.metrics_registry.snapshot()
        # per-phase p95s + cost-model counters ride the report so
        # check_bench --baseline can attribute a regression to the phase
        # / cost counter that moved (docs/serving.md "Observability")
        phases = {k: round(s["p95"], 6)
                  for k, s in snap["histograms"].items()
                  if k.endswith("_s") and s["count"]}
        cost = {k: v for k, v in snap["counters"].items()
                if k.startswith("cost.") and "." not in k[5:]}
        return {"wall_s": round(dt, 3),
                "phases": phases, "cost": cost,
                "tok_per_s": round(total_tokens / dt, 1),
                "steps": hs["count"],
                "model_dispatches":
                    eng.metrics_registry.counter(
                        "step.model_dispatches").value,
                "p50_step_s": round(hs["p50"], 5),
                "p95_step_s": round(hs["p95"], 5),
                "max_step_s": round(hs["max"], 5),
                "ttft_p50_s": round(ts["ttft_s"]["p50"], 5),
                "ttft_p95_s": round(ts["ttft_s"]["p95"], 5),
                "tpot_p50_s": round(ts["tpot_s"]["p50"], 6),
                "queue_wait_p95_s": round(ts["queue_wait_s"]["p95"], 5)}

    # warmup pass compiles every prefill/decode shape; reset() keeps the
    # jit caches (and clears metrics + trace), so the measured passes are
    # steady-state serving with clean counters. Each metric takes its
    # best pass — host scheduling noise (GC, interrupts) only ever
    # worsens a pass, while a real regression shifts them all.
    one_pass()
    best = None
    for _ in range(passes):
        eng.reset()
        cur = pass_report(one_pass())
        if best is None:
            best = cur
        else:
            best["tok_per_s"] = max(best["tok_per_s"], cur["tok_per_s"])
            for k in ("wall_s", "p50_step_s", "p95_step_s", "max_step_s",
                      "ttft_p50_s", "ttft_p95_s", "tpot_p50_s",
                      "queue_wait_p95_s"):
                best[k] = min(best[k], cur[k])
            # best-of per phase (noise only worsens a pass); cost counters
            # are deterministic given the traffic, keep the last pass's
            best["phases"] = {
                k: min(best["phases"].get(k, v), v)
                for k, v in cur["phases"].items()}
            best["cost"] = cur["cost"]
    if artifacts_dir:
        # last measured pass's lifecycle trace + Chrome trace + unified
        # metrics snapshot
        tag = artifact_tag or name
        os.makedirs(artifacts_dir, exist_ok=True)
        eng.tracer.export_jsonl(
            os.path.join(artifacts_dir, f"trace_{tag}.jsonl"))
        eng.tracer.export_chrome_trace(
            os.path.join(artifacts_dir, f"chrome_trace_{tag}.json"))
        with open(os.path.join(artifacts_dir,
                               f"metrics_{tag}.json"), "w") as f:
            json.dump(eng.metrics(), f, indent=2, sort_keys=True)
            f.write("\n")
    rep = {"workload": name, "engine": "continuous", "packed": packed,
           "prefix_cache": eng.prefix_cache is not None,
           "prefill_chunk": eng.prefill_chunk,
           "paged_impl": eng.paged_impl,
           "fused_step": eng.fused_step,
           "spec_decode": eng.spec_decode,
           "requests": len(reqs), "n_slots": n_slots,
           "gen_tokens": total_tokens, **best}
    if spec_decode:
        # accept accounting from the last measured pass — the traffic is
        # deterministic, so the accept pattern is identical across passes
        c = eng.metrics_registry.snapshot()["counters"]
        rep["spec_k"] = spec_k
        rep["draft_slices"] = draft_slices
        rep["spec_steps"] = c.get("spec.steps", 0)
        rep["spec_proposed"] = c.get("spec.proposed", 0)
        rep["spec_accepted"] = c.get("spec.accepted", 0)
        rep["accept_rate"] = round(
            c.get("spec.accepted", 0) / max(c.get("spec.proposed", 0), 1), 3)
        rep["spec_tokens_per_step"] = round(
            c.get("spec.tokens", 0) / max(c.get("spec.steps", 0), 1), 3)
    if eng.prefix_cache is not None:
        rep["materializes_gathered_kv"] = eng.paged_impl is None
        rep["decode_gathered_bytes_per_step"] = _decode_gathered_bytes(
            eng, cfg)
    stats = eng.prefix_stats()
    prompt_tokens = sum(len(p) for p, _, _ in reqs)
    rep["prompt_tokens"] = prompt_tokens
    rep["prefill_tokens"] = stats["prefill_tokens"]
    if stats["enabled"]:
        rep["hit_rate"] = round(stats["hit_rate"], 3)
        rep["prefill_tokens_saved"] = stats["saved_tokens"]
        rep["evictions"] = stats["evictions"]
        rep["prefill_chunk_steps"] = stats["prefill_chunk_steps"]
    return rep


GATED_FIELDS = (
    # (field, direction: +1 means higher-is-better, -1 lower-is-better)
    ("tok_per_s", +1),
    ("p95_step_s", -1),
)

# --update-baseline records measured * headroom, not the raw measurement:
# the committed baseline is the *floor of acceptable*, and the check
# tolerance sits on top of it. CPU smoke numbers are noisy at the
# millisecond scale and CI runners are slower than dev machines, and the
# gate's job is catching step-function regressions (an order-of-magnitude
# cliff), not re-measuring the trajectory — that is what the
# BENCH_serve.json artifact records.
BASELINE_HEADROOM = {"tok_per_s": 0.5, "p95_step_s": 2.0}


def check_baseline(results, baseline, tolerance):
    """Return a list of regression strings: any gated field more than
    ``tolerance`` (fraction) worse than the committed baseline."""
    regressions = []
    for name, base in baseline.items():
        cur = results.get(name)
        if cur is None:
            regressions.append(f"{name}: workload missing from this run")
            continue
        for field, sign in GATED_FIELDS:
            if field not in base:
                continue
            want, got = float(base[field]), float(cur[field])
            if sign > 0:
                ok = got >= want * (1.0 - tolerance)
            else:
                ok = got <= want * (1.0 + tolerance)
            if not ok:
                regressions.append(
                    f"{name}.{field}: {got} vs baseline {want} "
                    f"(tolerance {tolerance:.0%})")
    return regressions


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--packed", action="store_true")
    ap.add_argument("--n-shifts", type=int, default=4)
    ap.add_argument("--workloads", default="uniform,mixed,shared_prefix")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="contiguous per-slot KV (no block sharing)")
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked prefill: at most this many prompt tokens "
                         "per step (long_prompt defaults to "
                         f"{LONG_PREFILL_CHUNK})")
    ap.add_argument("--json-out", default=None,
                    help="write one JSON object per workload to this file")
    ap.add_argument("--artifacts-dir", default=None, metavar="DIR",
                    help="export per-workload trace JSONL + engine.metrics()"
                         " snapshots (CI observability artifacts)")
    ap.add_argument("--check-baseline", default=None, metavar="PATH",
                    help="fail if tok/s or p95 step latency regresses vs "
                         "this baseline JSON")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite --check-baseline PATH from this run")
    ap.add_argument("--baseline-tolerance", type=float, default=0.25)
    ap.add_argument("--passes", type=int, default=3,
                    help="measured passes per workload (best-of)")
    args = ap.parse_args()
    if args.update_baseline and not args.check_baseline:
        ap.error("--update-baseline needs --check-baseline PATH to write")

    cfg = C.get_smoke(args.arch).replace(compute_dtype="float32")
    params = pp.init_params(Model(cfg).build(), jax.random.key(0))
    qcfg = QuantConfig(method="swis", n_shifts=args.n_shifts, group_size=4)

    names = [n.strip() for n in args.workloads.split(",")]
    unknown = [n for n in names if n not in WORKLOADS]
    if unknown:
        ap.error(f"unknown workload(s) {unknown}; "
                 f"choose from {sorted(WORKLOADS)}")
    common = dict(n_slots=args.n_slots, requests=args.requests,
                  packed=args.packed, qcfg=qcfg,
                  prefix_cache=not args.no_prefix_cache,
                  block_size=args.block_size, passes=args.passes,
                  artifacts_dir=args.artifacts_dir)
    results = {}
    for name in names:
        if name == "long_prompt" and not args.no_prefix_cache:
            chunk = args.prefill_chunk or LONG_PREFILL_CHUNK
            rep = run_workload(name, cfg, params, prefill_chunk=chunk,
                               **common)
            rep_un = run_workload(name, cfg, params, prefill_chunk=None,
                                  artifact_tag=f"{name}_unchunked",
                                  **common)
            rep["p95_step_s_unchunked"] = rep_un["p95_step_s"]
            rep["p95_step_speedup"] = round(
                rep_un["p95_step_s"] / rep["p95_step_s"], 2)
            print(json.dumps(rep_un))
        elif name == "decode_heavy" and not args.no_prefix_cache:
            # fused paged decode vs the gather reference on the same
            # traffic: the fused report is the gated one, with the gather
            # pass's latency and gathered-copy size alongside
            rep = run_workload(name, cfg, params, use_paged_kernel=True,
                               prefill_chunk=args.prefill_chunk, **common)
            rep_g = run_workload(name, cfg, params, use_paged_kernel=False,
                                 prefill_chunk=args.prefill_chunk,
                                 artifact_tag=f"{name}_gather", **common)
            rep["p50_step_s_gather"] = rep_g["p50_step_s"]
            rep["p95_step_s_gather"] = rep_g["p95_step_s"]
            rep["decode_gathered_bytes_per_step_gather"] = \
                rep_g["decode_gathered_bytes_per_step"]
            rep["paged_p95_speedup"] = round(
                rep_g["p95_step_s"] / rep["p95_step_s"], 2)
            print(json.dumps(rep_g))
        elif name == "mixed_load" and not args.no_prefix_cache:
            # fused mixed step vs the separate chunk-then-decode path on
            # the same traffic: the fused report is the gated one, with
            # the separate pass's latency and dispatch count alongside —
            # the dispatch delta is the fused step's structural win
            chunk = args.prefill_chunk or MIXED_PREFILL_CHUNK
            rep = run_workload(name, cfg, params, prefill_chunk=chunk,
                               fused_step=True, **common)
            rep_s = run_workload(name, cfg, params, prefill_chunk=chunk,
                                 fused_step=False,
                                 artifact_tag=f"{name}_separate", **common)
            rep["p50_step_s_separate"] = rep_s["p50_step_s"]
            rep["p95_step_s_separate"] = rep_s["p95_step_s"]
            rep["model_dispatches_separate"] = rep_s["model_dispatches"]
            rep["fused_p95_speedup"] = round(
                rep_s["p95_step_s"] / rep["p95_step_s"], 2)
            print(json.dumps(rep_s))
        elif name == "spec_decode" and not args.no_prefix_cache:
            # self-speculative decode vs plain decode on the same traffic,
            # both serving packed weights (the truncated-slice draft only
            # exists on the packed kernel path). The spec report is the
            # gated one; per-step latency is normalized per TOKEN on BOTH
            # sides (a plain step emits up to n_slots tokens, a spec step
            # several per row), so the speedup compares token cost, not
            # step cost
            spec_common = {**common, "packed": True}
            draft = max(1, args.n_shifts - 1)
            rep = run_workload(name, cfg, params, spec_decode=True,
                               draft_slices=draft,
                               prefill_chunk=args.prefill_chunk,
                               **spec_common)
            rep_p = run_workload(name, cfg, params,
                                 prefill_chunk=args.prefill_chunk,
                                 artifact_tag=f"{name}_plain", **spec_common)
            rep["p95_step_s_plain"] = rep_p["p95_step_s"]
            rep["model_dispatches_plain"] = rep_p["model_dispatches"]
            tok_per_step = rep["gen_tokens"] / max(rep["steps"], 1)
            tok_per_step_p = rep_p["gen_tokens"] / max(rep_p["steps"], 1)
            per_token = rep["p95_step_s"] / max(tok_per_step, 1e-9)
            per_token_p = rep_p["p95_step_s"] / max(tok_per_step_p, 1e-9)
            rep["spec_p95_speedup"] = round(per_token_p / per_token, 2)
            print(json.dumps(rep_p))
        else:
            rep = run_workload(name, cfg, params,
                               prefill_chunk=args.prefill_chunk, **common)
        print(json.dumps(rep))
        results[name] = rep

    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(results, f, indent=2, sort_keys=True)
            f.write("\n")
    if args.check_baseline:
        if args.update_baseline:
            # gated fields carry headroom; phase p95s get the same 2x
            # latency headroom; cost counters are recorded raw (they are
            # deterministic model outputs, not measurements — any drift
            # is a real cost-model/dispatch change worth naming)
            base = {}
            for name, rep in results.items():
                entry = {field: round(rep[field]
                                      * BASELINE_HEADROOM[field], 5)
                         for field, _ in GATED_FIELDS}
                entry["phases"] = {k: round(v * 2.0, 6)
                                   for k, v in rep.get("phases", {}).items()}
                entry["cost"] = rep.get("cost", {})
                base[name] = entry
            with open(args.check_baseline, "w") as f:
                json.dump(base, f, indent=2, sort_keys=True)
                f.write("\n")
            print(f"baseline updated: {args.check_baseline}", file=sys.stderr)
            return
        with open(args.check_baseline) as f:
            baseline = json.load(f)
        regressions = check_baseline(results, baseline,
                                     args.baseline_tolerance)
        if regressions:
            for r in regressions:
                print(f"PERF REGRESSION {r}", file=sys.stderr)
            sys.exit(1)
        print("baseline check passed", file=sys.stderr)


if __name__ == "__main__":
    main()
