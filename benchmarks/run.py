# One function per paper table. Print ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import paper_tables

    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    for fn in paper_tables.ALL:
        if only and only not in fn.__name__:
            continue
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.1f},{derived}")
        except Exception:
            print(f"{fn.__name__},0.0,ERROR", flush=True)
            traceback.print_exc(file=sys.stderr)


if __name__ == "__main__":
    main()
