"""One function per paper table/figure. Each returns rows
(name, us_per_call, derived) for the CSV printed by benchmarks.run."""
from __future__ import annotations

import time
from typing import List, Tuple

import jax.numpy as jnp
import numpy as np

from benchmarks.common import quant_policy, time_us, trained_smoke_model

Row = Tuple[str, float, str]


# ---------------------------------------------------------------------------
# Fig. 2 — P(lossless quantization), Eqs. 8-10
# ---------------------------------------------------------------------------

def fig2_lossless_probability() -> List[Row]:
    from repro.core import probability as P

    rows: List[Row] = []
    us = time_us(lambda: P.lossless_table(), n=10)
    for n in range(1, 9):
        rows.append((f"fig2/swis/N{n}", us, f"{P.p_lossless_swis(n):.6f}"))
        rows.append((f"fig2/swis_c/N{n}", us, f"{P.p_lossless_swis_c(n):.6f}"))
        rows.append((f"fig2/layerwise/N{n}", us,
                     f"{P.p_lossless_layerwise(n):.6f}"))
    return rows


# ---------------------------------------------------------------------------
# Table 1 — RMSE of SWIS / SWIS-C / layer-wise truncation
# ---------------------------------------------------------------------------

def table1_rmse() -> List[Row]:
    from repro.core.swis import QuantConfig, fake_quant, rmse

    rows: List[Row] = []
    rng = np.random.default_rng(0)
    # resnet18-conv1-like (K=7*7*3 -> 148 padded; bell-shaped) and
    # mobilenet-pw1-like (K=32; heavier tails) weight matrices
    layers = {
        "resnet_conv": rng.normal(0, 0.05, (148, 64)).astype(np.float32),
        "mobilenet_pw": (rng.standard_t(4, (32, 96)) * 0.04).astype(np.float32),
    }
    for lname, w in layers.items():
        wj = jnp.asarray(w)
        for g in (1, 4):
            for n in (2, 3, 4, 5):
                for m in ("swis", "swis_c", "trunc"):
                    if g == 1 and m == "trunc":
                        g_eff = 1
                    cfg = QuantConfig(method=m, n_shifts=n, group_size=g)

                    def f(cfg=cfg):
                        return rmse(wj, fake_quant(wj, cfg))
                    us = time_us(f, n=1)
                    rows.append((f"table1/{lname}/g{g}/N{n}/{m}", us,
                                 f"{float(f()):.5f}"))
    return rows


# ---------------------------------------------------------------------------
# Fig. 5 — weight storage compression ratios (+ DPRed)
# ---------------------------------------------------------------------------

def fig5_compression() -> List[Row]:
    from repro.core.packing import compression_ratio, dpred_compression

    rows: List[Row] = []
    rng = np.random.default_rng(0)
    mags = np.abs(rng.normal(0, 24, (4096, 64))).clip(0, 255).round()
    for g in (2, 4, 8, 16):
        for n in (2, 3, 4, 5, 6):
            rows.append((f"fig5/swis/g{g}/N{n}", 0.0,
                         f"{compression_ratio(g, n, 'swis'):.3f}"))
            rows.append((f"fig5/swis_c/g{g}/N{n}", 0.0,
                         f"{compression_ratio(g, n, 'swis_c'):.3f}"))
        rows.append((f"fig5/dpred/g{g}", 0.0,
                     f"{dpred_compression(mags, g):.3f}"))
    return rows


# ---------------------------------------------------------------------------
# Fig. 3 — PE area / energy / throughput-per-area
# ---------------------------------------------------------------------------

def fig3_pe() -> List[Row]:
    from repro.perfmodel.pe import PE_LIBRARY

    rows: List[Row] = []
    for name in ("swis_ss", "swis_ds"):
        pe = PE_LIBRARY[name]
        for n in (2, 4, 6):
            e = pe.energy_per_mac_pj(n)
            rows.append((f"fig3/{name}/energy_pj/N{n}", 0.0, f"{e:.4f}"))
            tpa = pe.macs_per_cycle(n) / pe.area_mm2()
            rows.append((f"fig3/{name}/macs_per_cyc_mm2/N{n}", 0.0,
                         f"{tpa:.1f}"))
        rows.append((f"fig3/{name}/area_mm2", 0.0, f"{pe.area_mm2():.5f}"))
    return rows


# ---------------------------------------------------------------------------
# Fig. 1 — DRAM weight/activation access ratio (ResNet-18)
# ---------------------------------------------------------------------------

def fig1_dram_ratio() -> List[Row]:
    from repro.perfmodel.evaluate import fig1_dram_ratio as f1

    rows = []
    for name, ratio in f1():
        rows.append((f"fig1/resnet18/{name}", 0.0, f"{ratio:.2f}"))
    return rows


# ---------------------------------------------------------------------------
# Table 4 — F/J and F/s for all accelerator configs
# ---------------------------------------------------------------------------

def table4_performance() -> List[Row]:
    from repro.perfmodel.evaluate import evaluate_table4, headline_ratios

    rows: List[Row] = []
    t0 = time.perf_counter()
    table = evaluate_table4()
    us = (time.perf_counter() - t0) * 1e6 / max(len(table), 1)
    for r in table:
        key = f"table4/{r['network']}/{r['point']}/{r['config']}/S{r['n_shifts']}"
        rows.append((key + "/fps", us, f"{r['frames_per_s']:.2f}"))
        rows.append((key + "/fpj", us, f"{r['frames_per_j']:.2f}"))
    for k, v in headline_ratios().items():
        rows.append((f"table4/headline/{k}", 0.0, f"{v:.3f}"))
    return rows


# ---------------------------------------------------------------------------
# Table 3 — post-training quantization accuracy (synthetic task; orderings)
# ---------------------------------------------------------------------------

def table3_ptq() -> List[Row]:
    cfg, params, eval_acc = trained_smoke_model()
    rows: List[Row] = []
    base = eval_acc(cfg)
    rows.append(("table3/baseline_fp32", 0.0, f"{base:.4f}"))
    for n in (2, 2.5, 3, 4):
        for m, ds in (("swis", False), ("swis", True), ("swis_c", False),
                      ("swis_c", True)):
            qcfg = cfg.replace(quant=quant_policy(m, n, ds=ds))
            t0 = time.perf_counter()
            acc = eval_acc(qcfg)
            us = (time.perf_counter() - t0) * 1e6
            tag = "ds" if ds else "ss"
            rows.append((f"table3/{m}_{tag}/N{n}", us, f"{acc:.4f}"))
        if float(n).is_integer():
            qcfg = cfg.replace(quant=quant_policy("trunc", n))
            rows.append((f"table3/wgt_trunc/N{n}", 0.0,
                         f"{eval_acc(qcfg):.4f}"))
            qcfg = cfg.replace(quant=quant_policy("act_trunc", n))
            rows.append((f"table3/act_trunc/N{n}", 0.0,
                         f"{eval_acc(qcfg):.4f}"))
    return rows


# ---------------------------------------------------------------------------
# Table 2 / §4.3 — filter scheduling benefit
# ---------------------------------------------------------------------------

def table2_scheduling() -> List[Row]:
    cfg, params, eval_acc = trained_smoke_model()
    rows: List[Row] = []
    for n in (2, 2.5, 3):
        for ds in (False, True):
            qcfg = cfg.replace(quant=quant_policy("swis", n, ds=ds,
                                                  schedule=True))
            tag = "double" if ds else "single"
            rows.append((f"table2/sched_{tag}/N{n}", 0.0,
                         f"{eval_acc(qcfg):.4f}"))
        if float(n).is_integer():
            qcfg = cfg.replace(quant=quant_policy("swis", n, schedule=False))
            rows.append((f"table2/none/N{n}", 0.0, f"{eval_acc(qcfg):.4f}"))
    # offline exact scheduler (§4.3 two-phase) on a real weight matrix
    from repro.core import scheduling
    from repro.core.swis import QuantConfig, _to_int_domain, _column_costs

    w = params["blocks"]["sub0_attn"]["mlp"]["wi"]["w"][0]
    qc = QuantConfig(n_shifts=3, group_size=4)
    mags, signs, _ = _to_int_domain(jnp.asarray(w, jnp.float32), 8, False)

    def cost_fn(n):
        _, c = _column_costs(mags, signs, n, qc)
        return np.asarray(c)

    sched25 = scheduling.schedule_layer(cost_fn, 2.5, levels=[1, 2, 3, 4],
                                        sa_cols=8)
    rows.append(("table2/offline/effective_shifts", 0.0,
                 f"{sched25.effective_shifts:.3f}"))
    # iso-budget: scheduled average-3 must never cost more than uniform 3
    sched3 = scheduling.schedule_layer(cost_fn, 3.0, levels=[2, 3, 4],
                                       sa_cols=8)
    uniform3 = float(cost_fn(3).sum())
    rows.append(("table2/offline/cost_sched3_vs_uniform3", 0.0,
                 f"{sched3.total_cost / uniform3:.3f}"))
    # the fractional point sits strictly between its integer neighbours
    uniform2 = float(cost_fn(2).sum())
    rows.append(("table2/offline/cost_sched2.5_vs_uniform2", 0.0,
                 f"{sched25.total_cost / uniform2:.3f}"))
    return rows


# ---------------------------------------------------------------------------
# Table 5 — quantization-aware retraining recovers accuracy
# ---------------------------------------------------------------------------

def table5_retraining() -> List[Row]:
    from repro.train.loop import Trainer

    rows: List[Row] = []
    cfg, params, eval_acc = trained_smoke_model()
    n = 2
    ptq = cfg.replace(quant=quant_policy("swis", n))
    acc_ptq = eval_acc(ptq)
    rows.append((f"table5/ptq_swis/N{n}", 0.0, f"{acc_ptq:.4f}"))
    # QAT: continue training WITH swis fake-quant in the graph (STE)
    qat_cfg = cfg.replace(quant=quant_policy("swis", n))
    qat_cfg = qat_cfg.replace(quant=qat_cfg.quant.__class__(
        cfg=qat_cfg.quant.cfg, mode="qat"))
    # Table 5 = RETRAINING: warm-start from a COPY of the fp32-trained
    # weights (the train step donates its state; identity tree.map would
    # alias — and invalidate — the shared cached params)
    import jax as _jax
    import jax.numpy as _jnp

    tr = Trainer(qat_cfg, seq_len=64, global_batch=16, total_steps=150,
                 warmup=10, peak_lr=5e-4,
                 init_params=_jax.tree.map(_jnp.array, params))
    t0 = time.perf_counter()
    out = tr.run(150)
    us = (time.perf_counter() - t0) * 1e6 / 150
    acc_qat = eval_acc(ptq, eval_params=out["state"].params)
    rows.append((f"table5/qat_swis/N{n}", us, f"{acc_qat:.4f}"))
    trunc = cfg.replace(quant=quant_policy("trunc", n))
    rows.append((f"table5/ptq_trunc/N{n}", 0.0, f"{eval_acc(trunc):.4f}"))
    return rows


# ---------------------------------------------------------------------------
# Fig. 6 — accuracy (RMSE proxy + task accuracy) vs group size
# ---------------------------------------------------------------------------

def fig6_groupsize() -> List[Row]:
    from repro.core.swis import QuantConfig, fake_quant, rmse

    cfg, params, eval_acc = trained_smoke_model()
    rows: List[Row] = []
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(0, 0.04, (256, 128)).astype(np.float32))
    for g in (1, 2, 4, 8, 16):
        for n in (2, 3, 4):
            for m in ("swis", "swis_c"):
                q = fake_quant(w, QuantConfig(method=m, n_shifts=n,
                                              group_size=g))
                rows.append((f"fig6/rmse/{m}/g{g}/N{n}", 0.0,
                             f"{float(rmse(w, q)):.5f}"))
    for g in (2, 4, 8):
        qcfg = cfg.replace(quant=quant_policy("swis", 3, group=g))
        rows.append((f"fig6/acc/swis/g{g}/N3", 0.0, f"{eval_acc(qcfg):.4f}"))
    return rows


# ---------------------------------------------------------------------------
# Kernel microbenchmark (Pallas interpret vs jnp reference)
# ---------------------------------------------------------------------------

def kernel_bench() -> List[Row]:
    from repro.core import packing, swis
    from repro.kernels import ops

    rows: List[Row] = []
    rng = np.random.default_rng(0)
    for (mm, kk, nn, g, ns) in [(64, 512, 256, 4, 3), (128, 1024, 512, 8, 2)]:
        w = rng.normal(0, 0.05, (kk, nn)).astype(np.float32)
        x = jnp.asarray(rng.normal(0, 1, (mm, kk)).astype(np.float32))
        qw = swis.quantize(jnp.asarray(w),
                           swis.QuantConfig(n_shifts=ns, group_size=g))
        pw = packing.pack(qw)
        us_ref = time_us(lambda: ops.swis_matmul(x, pw, use_pallas=False))
        us_pal = time_us(lambda: ops.swis_matmul(x, pw, use_pallas=True,
                                                 interpret=True))
        rows.append((f"kernel/swis_matmul_ref/{mm}x{kk}x{nn}/g{g}N{ns}",
                     us_ref, "jnp"))
        rows.append((f"kernel/swis_matmul_pallas/{mm}x{kk}x{nn}/g{g}N{ns}",
                     us_pal, "interpret"))
        us_q = time_us(lambda: swis.fake_quant(
            jnp.asarray(w), swis.QuantConfig(n_shifts=ns, group_size=g)))
        rows.append((f"kernel/quantize/{kk}x{nn}/g{g}N{ns}", us_q, "ptq"))
    return rows


ALL = [
    fig2_lossless_probability,
    table1_rmse,
    fig5_compression,
    fig3_pe,
    fig1_dram_ratio,
    table4_performance,
    table2_scheduling,
    table3_ptq,
    table5_retraining,
    fig6_groupsize,
    kernel_bench,
]
