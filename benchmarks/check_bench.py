"""CI assertions over a serve_bench JSON report (``--json-out`` format).

Replaces the old inline-heredoc CI step: given ``BENCH_serve.json`` (a
dict keyed by workload), assert the serving stack's headline wins are
actually present in the run —

* ``shared_prefix``: the radix prefix cache hit (hit_rate > 0) and saved
  prefill tokens (prefill_tokens_saved > 0);
* ``long_prompt``: chunked prefill bounded per-step latency — p95 step
  wall time at least ``--min-speedup`` (default 2x) lower than the
  unchunked pass recorded in the same report. The speedup field is
  *required*: a report that silently lost the chunked/unchunked
  comparison (e.g. a --no-prefix-cache run fed to CI by mistake) fails
  instead of passing vacuously. ``--allow-missing-speedup`` restores the
  old skip for runs where the comparison is knowingly absent;
* ``decode_heavy``: the fused paged-decode pass must not materialize
  gathered K/V and its p95 step latency must be no worse than the gather
  reference pass (``--min-paged-speedup``, default 1.0, with a small
  tolerance for CPU timer noise);
* ``mixed_load``: the fused mixed-step pass must actually run fused
  (``fused_step`` true), issue strictly fewer model dispatches than the
  separate chunk-then-decode pass on the same traffic, and its p95 step
  latency must be no worse than the separate pass
  (``--min-fused-speedup``, default 1.0, same noise tolerance);
* ``spec_decode``: the gated pass must actually speculate
  (``spec_decode`` true, ``spec_proposed`` > 0), its accept rate must be
  positive (a draft that never matches the verify targets means the
  truncated-slice draft is broken, not just slow), and its PER-TOKEN p95
  step latency — ``p95_step_s`` over tokens emitted per step, for both
  the spec and the plain pass — must clear
  ``--min-spec-speedup`` vs the plain-decode pass (default 0.5: a
  sequential-launch draft on CPU is expected to cost wall time; the gate
  is a collapse floor, the accept/dispatch accounting is the signal).

Workloads absent from the report are skipped, so the script composes with
any ``--workloads`` selection. Exits non-zero with a reason on failure.

``--require-metrics DIR`` additionally validates the observability
artifacts ``serve_bench.py --artifacts-dir`` exported: for every workload
in the report there must be a ``metrics_<workload>.json`` snapshot with
the unified ``engine.metrics()`` sections, required keys, and non-zero
cost-model counters (``cost.flops`` / ``cost.hbm_bytes`` /
``cost.swis_cycles``); a non-empty ``trace_<workload>.jsonl`` lifecycle
trace; and a ``chrome_trace_<workload>.json`` that passes the Chrome
trace-event schema smoke check (valid JSON, ``ph``/``ts``/``pid`` on
every event, at least one ``step`` span with a phase span nested inside
it). Failures name the workload and the missing key/file (actionable,
not a bare assert).

``--baseline PATH`` (the serve_bench ``--update-baseline`` file) turns an
opaque perf regression into an attributed one: for every workload the
baseline's per-phase p95s and cost counters are compared against the
report, and a failure names *which phase* slowed down or *which cost
counter* moved (tolerance ``--baseline-tolerance``, default 25%; cost
counters are deterministic, so any relative drift beyond tolerance — in
either direction — is flagged as an unacknowledged cost-model/dispatch
change).

Usage: python benchmarks/check_bench.py BENCH_serve.json [--min-speedup 2]
           [--require-metrics artifacts/] [--baseline benchmarks/baseline.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# engine.metrics() contract the artifacts must satisfy (see
# docs/serving.md "Observability" for the full name/units table)
REQUIRED_SECTIONS = ("engine", "scheduler", "prefix_cache", "trace")
REQUIRED_PHASES = ("step.total_s",)
REQUIRED_SCHEDULER_KEYS = ("queue_depth", "active_slots",
                           "prefilling_slots", "decoding_slots",
                           "submitted", "finished")
REQUIRED_PREFIX_KEYS = ("enabled", "prefill_tokens", "saved_tokens")
REQUIRED_POOL_KEYS = ("n_blocks", "free_blocks", "used_blocks",
                      "occupancy")
# cost-model counters every instrumented run must have recorded (global
# totals; per-kind cost.<kind>.* counters ride alongside)
REQUIRED_COST_COUNTERS = ("cost.flops", "cost.hbm_bytes",
                          "cost.swis_cycles")


def check_chrome_trace(path):
    """Schema smoke check over an exported Chrome trace-event JSON.
    Returns a list of error strings (empty = passes): valid JSON with a
    non-empty ``traceEvents`` list, ``ph``/``ts``/``pid`` on every
    event, at least one ``X`` span named ``step``, and at least one
    phase span nested inside a step span by timestamp containment —
    the structure Perfetto renders as the step -> phase hierarchy."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable Chrome trace ({e})"]
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        return [f"{path}: traceEvents missing or empty"]
    errors = []
    for i, e in enumerate(events):
        for key in ("ph", "ts", "pid"):
            if key not in e:
                errors.append(f"{path}: event {i} missing {key!r}")
                break
    spans = [e for e in events if e.get("ph") == "X"]
    steps = [e for e in spans if e.get("name") == "step"]
    if not steps:
        errors.append(f"{path}: no 'step' span — phase spans have "
                      f"nothing to nest under")
        return errors
    nested = False
    for e in spans:
        if e.get("name") == "step" or e.get("pid") != steps[0].get("pid"):
            continue
        for s in steps:
            if (s["ts"] <= e["ts"]
                    and e["ts"] + e.get("dur", 0)
                    <= s["ts"] + s.get("dur", 0) + 1e-6):
                nested = True
                break
        if nested:
            break
    if not nested:
        errors.append(f"{path}: no phase span nested inside a step span "
                      f"(timestamp containment) — the span hierarchy is "
                      f"broken")
    return errors


def attribute_regressions(results, baseline, tolerance=0.25):
    """Per-phase / per-cost-counter baseline comparison: the attribution
    layer behind the one-number gate. Returns error strings naming the
    workload AND the phase/counter that moved.

    Phases (p95 seconds, baseline already carries 2x headroom): fail when
    measured > baseline * (1 + tolerance). Cost counters (deterministic
    model outputs): fail when relative drift exceeds tolerance in either
    direction — costs that silently changed mean the dispatch pattern or
    the cost model changed, and that must be acknowledged by a baseline
    update."""
    errors = []
    for name, base in sorted(baseline.items()):
        cur = results.get(name)
        if cur is None:
            continue  # absent workloads are the gate's concern, not ours
        got_phases = cur.get("phases", {})
        for phase, want in sorted(base.get("phases", {}).items()):
            got = got_phases.get(phase)
            if got is None:
                errors.append(
                    f"{name}: phase {phase!r} in baseline but absent "
                    f"from this run — a phase stopped being recorded")
                continue
            if got > want * (1.0 + tolerance):
                errors.append(
                    f"{name}: phase {phase!r} regressed: p95 {got:.6f}s "
                    f"vs baseline {want:.6f}s "
                    f"(+{(got / want - 1.0):.0%}, tolerance "
                    f"{tolerance:.0%}) — this phase moved")
        got_cost = cur.get("cost", {})
        for counter, want in sorted(base.get("cost", {}).items()):
            got = got_cost.get(counter)
            if got is None:
                errors.append(
                    f"{name}: cost counter {counter!r} in baseline but "
                    f"absent from this run — cost recording broke")
                continue
            if want > 0 and abs(got / want - 1.0) > tolerance:
                errors.append(
                    f"{name}: cost counter {counter!r} moved: {got:g} vs "
                    f"baseline {want:g} ({got / want - 1.0:+.0%}) — "
                    f"dispatch pattern or cost model changed; update the "
                    f"baseline if intentional")
    return errors


def check_metrics(results, metrics_dir):
    """Validate the per-workload observability artifacts. Returns a list
    of error strings, each naming the workload and the offending
    key/file so the failure is actionable from the CI log alone."""
    errors = []
    for name in sorted(results):
        mpath = os.path.join(metrics_dir, f"metrics_{name}.json")
        if not os.path.exists(mpath):
            errors.append(f"{name}: metrics snapshot missing ({mpath}) — "
                          f"was serve_bench run with --artifacts-dir?")
            continue
        with open(mpath) as f:
            snap = json.load(f)
        for sec in REQUIRED_SECTIONS:
            if sec not in snap:
                errors.append(f"{name}: snapshot section {sec!r} missing "
                              f"from {mpath}")
        phases = snap.get("engine", {}).get("phases", {})
        for ph in REQUIRED_PHASES:
            if ph not in phases:
                errors.append(f"{name}: phase histogram {ph!r} missing "
                              f"from engine.phases in {mpath}")
            elif not phases[ph].get("count", 0) > 0:
                errors.append(f"{name}: phase histogram {ph!r} recorded "
                              f"zero observations in {mpath}")
        counters = snap.get("engine", {}).get("counters", {})
        for key in REQUIRED_COST_COUNTERS:
            if key not in counters:
                errors.append(f"{name}: cost counter {key!r} missing "
                              f"from engine.counters in {mpath}")
            elif not counters[key] > 0:
                errors.append(f"{name}: cost counter {key!r} recorded "
                              f"zero in {mpath} — dispatches were not "
                              f"costed")
        for key in REQUIRED_SCHEDULER_KEYS:
            if key not in snap.get("scheduler", {}):
                errors.append(f"{name}: scheduler gauge {key!r} missing "
                              f"from {mpath}")
        for key in REQUIRED_PREFIX_KEYS:
            if key not in snap.get("prefix_cache", {}):
                errors.append(f"{name}: prefix_cache key {key!r} missing "
                              f"from {mpath}")
        if snap.get("prefix_cache", {}).get("enabled"):
            for key in REQUIRED_POOL_KEYS:
                if key not in snap.get("block_pool", {}):
                    errors.append(f"{name}: block_pool gauge {key!r} "
                                  f"missing from {mpath}")
        tpath = os.path.join(metrics_dir, f"trace_{name}.jsonl")
        if not os.path.exists(tpath):
            errors.append(f"{name}: lifecycle trace missing ({tpath})")
        elif os.path.getsize(tpath) == 0:
            errors.append(f"{name}: lifecycle trace is empty ({tpath}) — "
                          f"was the engine built with enable_metrics="
                          f"False?")
        cpath = os.path.join(metrics_dir, f"chrome_trace_{name}.json")
        if not os.path.exists(cpath):
            errors.append(f"{name}: Chrome trace missing ({cpath})")
        else:
            errors += [f"{name}: {e}" for e in check_chrome_trace(cpath)]
    return errors


def check(results, min_speedup, min_paged_speedup=1.0,
          min_fused_speedup=1.0, min_spec_speedup=0.5,
          allow_missing_speedup=False, noise_tolerance=0.1):
    errors = []
    sp = results.get("shared_prefix")
    if sp is not None:
        if not sp.get("hit_rate", 0) > 0:
            errors.append(f"shared_prefix hit_rate not positive: {sp}")
        if not sp.get("prefill_tokens_saved", 0) > 0:
            errors.append(f"shared_prefix saved no prefill tokens: {sp}")
    lp = results.get("long_prompt")
    if lp is not None:
        if "p95_step_speedup" not in lp:
            if not allow_missing_speedup:
                errors.append(
                    "long_prompt has no p95_step_speedup (chunked vs "
                    "unchunked comparison missing — was this a "
                    "--no-prefix-cache run?); pass "
                    "--allow-missing-speedup if that is intentional")
        else:
            speedup = lp["p95_step_speedup"]
            if not speedup >= min_speedup:
                errors.append(
                    f"long_prompt p95 step speedup {speedup} < "
                    f"{min_speedup} (chunked {lp.get('p95_step_s')}s vs "
                    f"unchunked {lp.get('p95_step_s_unchunked')}s)")
    dh = results.get("decode_heavy")
    if dh is not None:
        if dh.get("materializes_gathered_kv", True):
            errors.append(
                f"decode_heavy fused pass materializes gathered K/V "
                f"(paged_impl={dh.get('paged_impl')!r}) — the paged "
                f"kernel was not in effect")
        if "paged_p95_speedup" not in dh:
            if not allow_missing_speedup:
                errors.append(
                    "decode_heavy has no paged_p95_speedup (fused vs "
                    "gather comparison missing); pass "
                    "--allow-missing-speedup if that is intentional")
        else:
            speedup = dh["paged_p95_speedup"]
            floor = min_paged_speedup * (1.0 - noise_tolerance)
            if not speedup >= floor:
                errors.append(
                    f"decode_heavy paged p95 step speedup {speedup} < "
                    f"{min_paged_speedup} (fused {dh.get('p95_step_s')}s "
                    f"vs gather {dh.get('p95_step_s_gather')}s)")
    ml = results.get("mixed_load")
    if ml is not None:
        if not ml.get("fused_step", False):
            errors.append(
                f"mixed_load gated pass did not run fused "
                f"(fused_step={ml.get('fused_step')!r}) — the mixed "
                f"dispatch was not in effect")
        if "model_dispatches_separate" in ml:
            fused_d = ml.get("model_dispatches")
            sep_d = ml["model_dispatches_separate"]
            if not (isinstance(fused_d, int) and fused_d < sep_d):
                errors.append(
                    f"mixed_load fused pass did not reduce model "
                    f"dispatches: {fused_d} vs separate {sep_d}")
        if "fused_p95_speedup" not in ml:
            if not allow_missing_speedup:
                errors.append(
                    "mixed_load has no fused_p95_speedup (fused vs "
                    "separate comparison missing); pass "
                    "--allow-missing-speedup if that is intentional")
        else:
            speedup = ml["fused_p95_speedup"]
            floor = min_fused_speedup * (1.0 - noise_tolerance)
            if not speedup >= floor:
                errors.append(
                    f"mixed_load fused p95 step speedup {speedup} < "
                    f"{min_fused_speedup} (fused {ml.get('p95_step_s')}s "
                    f"vs separate {ml.get('p95_step_s_separate')}s)")
    sd = results.get("spec_decode")
    if sd is not None:
        if not sd.get("spec_decode", False):
            errors.append(
                f"spec_decode gated pass did not speculate "
                f"(spec_decode={sd.get('spec_decode')!r})")
        if not sd.get("spec_proposed", 0) > 0:
            errors.append(
                f"spec_decode proposed no drafts (spec_proposed="
                f"{sd.get('spec_proposed')!r}) — speculative steps never "
                f"ran")
        if not sd.get("accept_rate", 0) > 0:
            errors.append(
                f"spec_decode accept_rate not positive: "
                f"{sd.get('accept_rate')!r} — the truncated-slice draft "
                f"never matched a verify target")
        if not isinstance(sd.get("model_dispatches"), int) or \
                not isinstance(sd.get("model_dispatches_plain"), int):
            errors.append(
                "spec_decode dispatch counts missing (model_dispatches / "
                "model_dispatches_plain) from the report")
        if "spec_p95_speedup" not in sd:
            if not allow_missing_speedup:
                errors.append(
                    "spec_decode has no spec_p95_speedup (spec vs plain "
                    "comparison missing); pass --allow-missing-speedup "
                    "if that is intentional")
        else:
            speedup = sd["spec_p95_speedup"]
            floor = min_spec_speedup * (1.0 - noise_tolerance)
            if not speedup >= floor:
                errors.append(
                    f"spec_decode per-token p95 speedup {speedup} < "
                    f"{min_spec_speedup} (spec {sd.get('p95_step_s')}s/"
                    f"step at {sd.get('spec_tokens_per_step')} tok/step "
                    f"vs plain {sd.get('p95_step_s_plain')}s)")
    return errors


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("report", help="serve_bench --json-out file")
    ap.add_argument("--min-speedup", type=float, default=2.0,
                    help="required p95 step-latency win of chunked over "
                         "unchunked prefill on the long_prompt workload")
    ap.add_argument("--min-paged-speedup", type=float, default=1.0,
                    help="required p95 step-latency ratio of the gather "
                         "reference over the fused paged decode on the "
                         "decode_heavy workload (1.0 = no worse)")
    ap.add_argument("--min-fused-speedup", type=float, default=1.0,
                    help="required p95 step-latency ratio of the separate "
                         "chunk-then-decode path over the fused mixed "
                         "step on the mixed_load workload (1.0 = no "
                         "worse)")
    ap.add_argument("--min-spec-speedup", type=float, default=0.5,
                    help="required PER-TOKEN p95 step-latency ratio of "
                         "plain decode over speculative decode on the "
                         "spec_decode workload (< 1.0 tolerated: the "
                         "sequential draft launches cost wall time on "
                         "CPU; this is a collapse floor)")
    ap.add_argument("--allow-missing-speedup", action="store_true",
                    help="skip (rather than fail) speedup assertions when "
                         "the comparison fields are absent from the report")
    ap.add_argument("--require-metrics", default=None, metavar="DIR",
                    help="validate the observability artifacts "
                         "(metrics_<workload>.json + trace_<workload>"
                         ".jsonl + chrome_trace_<workload>.json) "
                         "serve_bench exported into DIR")
    ap.add_argument("--baseline", default=None, metavar="PATH",
                    help="serve_bench baseline JSON: attribute any "
                         "per-phase p95 or cost-counter drift vs its "
                         "'phases'/'cost' entries to the phase/counter "
                         "that moved")
    ap.add_argument("--baseline-tolerance", type=float, default=0.25,
                    help="relative drift tolerated by --baseline "
                         "attribution (fraction, default 0.25)")
    args = ap.parse_args()
    with open(args.report) as f:
        results = json.load(f)
    errors = check(results, args.min_speedup, args.min_paged_speedup,
                   args.min_fused_speedup, args.min_spec_speedup,
                   args.allow_missing_speedup)
    if args.require_metrics:
        errors += check_metrics(results, args.require_metrics)
    if args.baseline:
        with open(args.baseline) as f:
            baseline = json.load(f)
        errors += attribute_regressions(results, baseline,
                                        args.baseline_tolerance)
    for e in errors:
        print(f"BENCH CHECK FAILED: {e}", file=sys.stderr)
    if errors:
        sys.exit(1)
    print(f"bench checks passed for {sorted(results)}"
          + (f" (+ metrics artifacts in {args.require_metrics})"
             if args.require_metrics else "")
          + (f" (+ phase/cost attribution vs {args.baseline})"
             if args.baseline else ""))


if __name__ == "__main__":
    main()
