"""CI assertions over a serve_bench JSON report (``--json-out`` format).

Replaces the old inline-heredoc CI step: given ``BENCH_serve.json`` (a
dict keyed by workload), assert the serving stack's two headline wins are
actually present in the run —

* ``shared_prefix``: the radix prefix cache hit (hit_rate > 0) and saved
  prefill tokens (prefill_tokens_saved > 0);
* ``long_prompt``: chunked prefill bounded per-step latency — p95 step
  wall time at least ``--min-speedup`` (default 2x) lower than the
  unchunked pass recorded in the same report.

Workloads absent from the report are skipped, so the script composes with
any ``--workloads`` selection. Exits non-zero with a reason on failure.

Usage: python benchmarks/check_bench.py BENCH_serve.json [--min-speedup 2]
"""
from __future__ import annotations

import argparse
import json
import sys


def check(results, min_speedup):
    errors = []
    sp = results.get("shared_prefix")
    if sp is not None:
        if not sp.get("hit_rate", 0) > 0:
            errors.append(f"shared_prefix hit_rate not positive: {sp}")
        if not sp.get("prefill_tokens_saved", 0) > 0:
            errors.append(f"shared_prefix saved no prefill tokens: {sp}")
    lp = results.get("long_prompt")
    if lp is not None and "p95_step_speedup" in lp:
        # absent with --no-prefix-cache (no chunked/unchunked comparison)
        speedup = lp["p95_step_speedup"]
        if not speedup >= min_speedup:
            errors.append(
                f"long_prompt p95 step speedup {speedup} < {min_speedup} "
                f"(chunked {lp.get('p95_step_s')}s vs unchunked "
                f"{lp.get('p95_step_s_unchunked')}s)")
    return errors


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("report", help="serve_bench --json-out file")
    ap.add_argument("--min-speedup", type=float, default=2.0,
                    help="required p95 step-latency win of chunked over "
                         "unchunked prefill on the long_prompt workload")
    args = ap.parse_args()
    with open(args.report) as f:
        results = json.load(f)
    errors = check(results, args.min_speedup)
    for e in errors:
        print(f"BENCH CHECK FAILED: {e}", file=sys.stderr)
    if errors:
        sys.exit(1)
    print(f"bench checks passed for {sorted(results)}")


if __name__ == "__main__":
    main()
