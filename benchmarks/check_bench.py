"""CI assertions over a serve_bench JSON report (``--json-out`` format).

Replaces the old inline-heredoc CI step: given ``BENCH_serve.json`` (a
dict keyed by workload), assert the serving stack's headline wins are
actually present in the run —

* ``shared_prefix``: the radix prefix cache hit (hit_rate > 0) and saved
  prefill tokens (prefill_tokens_saved > 0);
* ``long_prompt``: chunked prefill bounded per-step latency — p95 step
  wall time at least ``--min-speedup`` (default 2x) lower than the
  unchunked pass recorded in the same report. The speedup field is
  *required*: a report that silently lost the chunked/unchunked
  comparison (e.g. a --no-prefix-cache run fed to CI by mistake) fails
  instead of passing vacuously. ``--allow-missing-speedup`` restores the
  old skip for runs where the comparison is knowingly absent;
* ``decode_heavy``: the fused paged-decode pass must not materialize
  gathered K/V and its p95 step latency must be no worse than the gather
  reference pass (``--min-paged-speedup``, default 1.0, with a small
  tolerance for CPU timer noise).

Workloads absent from the report are skipped, so the script composes with
any ``--workloads`` selection. Exits non-zero with a reason on failure.

Usage: python benchmarks/check_bench.py BENCH_serve.json [--min-speedup 2]
"""
from __future__ import annotations

import argparse
import json
import sys


def check(results, min_speedup, min_paged_speedup=1.0,
          allow_missing_speedup=False, noise_tolerance=0.1):
    errors = []
    sp = results.get("shared_prefix")
    if sp is not None:
        if not sp.get("hit_rate", 0) > 0:
            errors.append(f"shared_prefix hit_rate not positive: {sp}")
        if not sp.get("prefill_tokens_saved", 0) > 0:
            errors.append(f"shared_prefix saved no prefill tokens: {sp}")
    lp = results.get("long_prompt")
    if lp is not None:
        if "p95_step_speedup" not in lp:
            if not allow_missing_speedup:
                errors.append(
                    "long_prompt has no p95_step_speedup (chunked vs "
                    "unchunked comparison missing — was this a "
                    "--no-prefix-cache run?); pass "
                    "--allow-missing-speedup if that is intentional")
        else:
            speedup = lp["p95_step_speedup"]
            if not speedup >= min_speedup:
                errors.append(
                    f"long_prompt p95 step speedup {speedup} < "
                    f"{min_speedup} (chunked {lp.get('p95_step_s')}s vs "
                    f"unchunked {lp.get('p95_step_s_unchunked')}s)")
    dh = results.get("decode_heavy")
    if dh is not None:
        if dh.get("materializes_gathered_kv", True):
            errors.append(
                f"decode_heavy fused pass materializes gathered K/V "
                f"(paged_impl={dh.get('paged_impl')!r}) — the paged "
                f"kernel was not in effect")
        if "paged_p95_speedup" not in dh:
            if not allow_missing_speedup:
                errors.append(
                    "decode_heavy has no paged_p95_speedup (fused vs "
                    "gather comparison missing); pass "
                    "--allow-missing-speedup if that is intentional")
        else:
            speedup = dh["paged_p95_speedup"]
            floor = min_paged_speedup * (1.0 - noise_tolerance)
            if not speedup >= floor:
                errors.append(
                    f"decode_heavy paged p95 step speedup {speedup} < "
                    f"{min_paged_speedup} (fused {dh.get('p95_step_s')}s "
                    f"vs gather {dh.get('p95_step_s_gather')}s)")
    return errors


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("report", help="serve_bench --json-out file")
    ap.add_argument("--min-speedup", type=float, default=2.0,
                    help="required p95 step-latency win of chunked over "
                         "unchunked prefill on the long_prompt workload")
    ap.add_argument("--min-paged-speedup", type=float, default=1.0,
                    help="required p95 step-latency ratio of the gather "
                         "reference over the fused paged decode on the "
                         "decode_heavy workload (1.0 = no worse)")
    ap.add_argument("--allow-missing-speedup", action="store_true",
                    help="skip (rather than fail) speedup assertions when "
                         "the comparison fields are absent from the report")
    args = ap.parse_args()
    with open(args.report) as f:
        results = json.load(f)
    errors = check(results, args.min_speedup, args.min_paged_speedup,
                   args.allow_missing_speedup)
    for e in errors:
        print(f"BENCH CHECK FAILED: {e}", file=sys.stderr)
    if errors:
        sys.exit(1)
    print(f"bench checks passed for {sorted(results)}")


if __name__ == "__main__":
    main()
