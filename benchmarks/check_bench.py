"""CI assertions over a serve_bench JSON report (``--json-out`` format).

Replaces the old inline-heredoc CI step: given ``BENCH_serve.json`` (a
dict keyed by workload), assert the serving stack's headline wins are
actually present in the run —

* ``shared_prefix``: the radix prefix cache hit (hit_rate > 0) and saved
  prefill tokens (prefill_tokens_saved > 0);
* ``long_prompt``: chunked prefill bounded per-step latency — p95 step
  wall time at least ``--min-speedup`` (default 2x) lower than the
  unchunked pass recorded in the same report. The speedup field is
  *required*: a report that silently lost the chunked/unchunked
  comparison (e.g. a --no-prefix-cache run fed to CI by mistake) fails
  instead of passing vacuously. ``--allow-missing-speedup`` restores the
  old skip for runs where the comparison is knowingly absent;
* ``decode_heavy``: the fused paged-decode pass must not materialize
  gathered K/V and its p95 step latency must be no worse than the gather
  reference pass (``--min-paged-speedup``, default 1.0, with a small
  tolerance for CPU timer noise);
* ``mixed_load``: the fused mixed-step pass must actually run fused
  (``fused_step`` true), issue strictly fewer model dispatches than the
  separate chunk-then-decode pass on the same traffic, and its p95 step
  latency must be no worse than the separate pass
  (``--min-fused-speedup``, default 1.0, same noise tolerance);
* ``spec_decode``: the gated pass must actually speculate
  (``spec_decode`` true, ``spec_proposed`` > 0), its accept rate must be
  positive (a draft that never matches the verify targets means the
  truncated-slice draft is broken, not just slow), and its PER-TOKEN p95
  step latency — ``p95_step_s`` over tokens emitted per step, for both
  the spec and the plain pass — must clear
  ``--min-spec-speedup`` vs the plain-decode pass (default 0.5: a
  sequential-launch draft on CPU is expected to cost wall time; the gate
  is a collapse floor, the accept/dispatch accounting is the signal).

Workloads absent from the report are skipped, so the script composes with
any ``--workloads`` selection. Exits non-zero with a reason on failure.

``--require-metrics DIR`` additionally validates the observability
artifacts ``serve_bench.py --artifacts-dir`` exported: for every workload
in the report there must be a ``metrics_<workload>.json`` snapshot with
the unified ``engine.metrics()`` sections and required keys, and a
non-empty ``trace_<workload>.jsonl`` lifecycle trace. Failures name the
workload and the missing key/file (actionable, not a bare assert).

Usage: python benchmarks/check_bench.py BENCH_serve.json [--min-speedup 2]
           [--require-metrics artifacts/]
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# engine.metrics() contract the artifacts must satisfy (see
# docs/serving.md "Observability" for the full name/units table)
REQUIRED_SECTIONS = ("engine", "scheduler", "prefix_cache", "trace")
REQUIRED_PHASES = ("step.total_s",)
REQUIRED_SCHEDULER_KEYS = ("queue_depth", "active_slots",
                           "prefilling_slots", "decoding_slots",
                           "submitted", "finished")
REQUIRED_PREFIX_KEYS = ("enabled", "prefill_tokens", "saved_tokens")
REQUIRED_POOL_KEYS = ("n_blocks", "free_blocks", "used_blocks",
                      "occupancy")


def check_metrics(results, metrics_dir):
    """Validate the per-workload observability artifacts. Returns a list
    of error strings, each naming the workload and the offending
    key/file so the failure is actionable from the CI log alone."""
    errors = []
    for name in sorted(results):
        mpath = os.path.join(metrics_dir, f"metrics_{name}.json")
        if not os.path.exists(mpath):
            errors.append(f"{name}: metrics snapshot missing ({mpath}) — "
                          f"was serve_bench run with --artifacts-dir?")
            continue
        with open(mpath) as f:
            snap = json.load(f)
        for sec in REQUIRED_SECTIONS:
            if sec not in snap:
                errors.append(f"{name}: snapshot section {sec!r} missing "
                              f"from {mpath}")
        phases = snap.get("engine", {}).get("phases", {})
        for ph in REQUIRED_PHASES:
            if ph not in phases:
                errors.append(f"{name}: phase histogram {ph!r} missing "
                              f"from engine.phases in {mpath}")
            elif not phases[ph].get("count", 0) > 0:
                errors.append(f"{name}: phase histogram {ph!r} recorded "
                              f"zero observations in {mpath}")
        for key in REQUIRED_SCHEDULER_KEYS:
            if key not in snap.get("scheduler", {}):
                errors.append(f"{name}: scheduler gauge {key!r} missing "
                              f"from {mpath}")
        for key in REQUIRED_PREFIX_KEYS:
            if key not in snap.get("prefix_cache", {}):
                errors.append(f"{name}: prefix_cache key {key!r} missing "
                              f"from {mpath}")
        if snap.get("prefix_cache", {}).get("enabled"):
            for key in REQUIRED_POOL_KEYS:
                if key not in snap.get("block_pool", {}):
                    errors.append(f"{name}: block_pool gauge {key!r} "
                                  f"missing from {mpath}")
        tpath = os.path.join(metrics_dir, f"trace_{name}.jsonl")
        if not os.path.exists(tpath):
            errors.append(f"{name}: lifecycle trace missing ({tpath})")
        elif os.path.getsize(tpath) == 0:
            errors.append(f"{name}: lifecycle trace is empty ({tpath}) — "
                          f"was the engine built with enable_metrics="
                          f"False?")
    return errors


def check(results, min_speedup, min_paged_speedup=1.0,
          min_fused_speedup=1.0, min_spec_speedup=0.5,
          allow_missing_speedup=False, noise_tolerance=0.1):
    errors = []
    sp = results.get("shared_prefix")
    if sp is not None:
        if not sp.get("hit_rate", 0) > 0:
            errors.append(f"shared_prefix hit_rate not positive: {sp}")
        if not sp.get("prefill_tokens_saved", 0) > 0:
            errors.append(f"shared_prefix saved no prefill tokens: {sp}")
    lp = results.get("long_prompt")
    if lp is not None:
        if "p95_step_speedup" not in lp:
            if not allow_missing_speedup:
                errors.append(
                    "long_prompt has no p95_step_speedup (chunked vs "
                    "unchunked comparison missing — was this a "
                    "--no-prefix-cache run?); pass "
                    "--allow-missing-speedup if that is intentional")
        else:
            speedup = lp["p95_step_speedup"]
            if not speedup >= min_speedup:
                errors.append(
                    f"long_prompt p95 step speedup {speedup} < "
                    f"{min_speedup} (chunked {lp.get('p95_step_s')}s vs "
                    f"unchunked {lp.get('p95_step_s_unchunked')}s)")
    dh = results.get("decode_heavy")
    if dh is not None:
        if dh.get("materializes_gathered_kv", True):
            errors.append(
                f"decode_heavy fused pass materializes gathered K/V "
                f"(paged_impl={dh.get('paged_impl')!r}) — the paged "
                f"kernel was not in effect")
        if "paged_p95_speedup" not in dh:
            if not allow_missing_speedup:
                errors.append(
                    "decode_heavy has no paged_p95_speedup (fused vs "
                    "gather comparison missing); pass "
                    "--allow-missing-speedup if that is intentional")
        else:
            speedup = dh["paged_p95_speedup"]
            floor = min_paged_speedup * (1.0 - noise_tolerance)
            if not speedup >= floor:
                errors.append(
                    f"decode_heavy paged p95 step speedup {speedup} < "
                    f"{min_paged_speedup} (fused {dh.get('p95_step_s')}s "
                    f"vs gather {dh.get('p95_step_s_gather')}s)")
    ml = results.get("mixed_load")
    if ml is not None:
        if not ml.get("fused_step", False):
            errors.append(
                f"mixed_load gated pass did not run fused "
                f"(fused_step={ml.get('fused_step')!r}) — the mixed "
                f"dispatch was not in effect")
        if "model_dispatches_separate" in ml:
            fused_d = ml.get("model_dispatches")
            sep_d = ml["model_dispatches_separate"]
            if not (isinstance(fused_d, int) and fused_d < sep_d):
                errors.append(
                    f"mixed_load fused pass did not reduce model "
                    f"dispatches: {fused_d} vs separate {sep_d}")
        if "fused_p95_speedup" not in ml:
            if not allow_missing_speedup:
                errors.append(
                    "mixed_load has no fused_p95_speedup (fused vs "
                    "separate comparison missing); pass "
                    "--allow-missing-speedup if that is intentional")
        else:
            speedup = ml["fused_p95_speedup"]
            floor = min_fused_speedup * (1.0 - noise_tolerance)
            if not speedup >= floor:
                errors.append(
                    f"mixed_load fused p95 step speedup {speedup} < "
                    f"{min_fused_speedup} (fused {ml.get('p95_step_s')}s "
                    f"vs separate {ml.get('p95_step_s_separate')}s)")
    sd = results.get("spec_decode")
    if sd is not None:
        if not sd.get("spec_decode", False):
            errors.append(
                f"spec_decode gated pass did not speculate "
                f"(spec_decode={sd.get('spec_decode')!r})")
        if not sd.get("spec_proposed", 0) > 0:
            errors.append(
                f"spec_decode proposed no drafts (spec_proposed="
                f"{sd.get('spec_proposed')!r}) — speculative steps never "
                f"ran")
        if not sd.get("accept_rate", 0) > 0:
            errors.append(
                f"spec_decode accept_rate not positive: "
                f"{sd.get('accept_rate')!r} — the truncated-slice draft "
                f"never matched a verify target")
        if not isinstance(sd.get("model_dispatches"), int) or \
                not isinstance(sd.get("model_dispatches_plain"), int):
            errors.append(
                "spec_decode dispatch counts missing (model_dispatches / "
                "model_dispatches_plain) from the report")
        if "spec_p95_speedup" not in sd:
            if not allow_missing_speedup:
                errors.append(
                    "spec_decode has no spec_p95_speedup (spec vs plain "
                    "comparison missing); pass --allow-missing-speedup "
                    "if that is intentional")
        else:
            speedup = sd["spec_p95_speedup"]
            floor = min_spec_speedup * (1.0 - noise_tolerance)
            if not speedup >= floor:
                errors.append(
                    f"spec_decode per-token p95 speedup {speedup} < "
                    f"{min_spec_speedup} (spec {sd.get('p95_step_s')}s/"
                    f"step at {sd.get('spec_tokens_per_step')} tok/step "
                    f"vs plain {sd.get('p95_step_s_plain')}s)")
    return errors


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("report", help="serve_bench --json-out file")
    ap.add_argument("--min-speedup", type=float, default=2.0,
                    help="required p95 step-latency win of chunked over "
                         "unchunked prefill on the long_prompt workload")
    ap.add_argument("--min-paged-speedup", type=float, default=1.0,
                    help="required p95 step-latency ratio of the gather "
                         "reference over the fused paged decode on the "
                         "decode_heavy workload (1.0 = no worse)")
    ap.add_argument("--min-fused-speedup", type=float, default=1.0,
                    help="required p95 step-latency ratio of the separate "
                         "chunk-then-decode path over the fused mixed "
                         "step on the mixed_load workload (1.0 = no "
                         "worse)")
    ap.add_argument("--min-spec-speedup", type=float, default=0.5,
                    help="required PER-TOKEN p95 step-latency ratio of "
                         "plain decode over speculative decode on the "
                         "spec_decode workload (< 1.0 tolerated: the "
                         "sequential draft launches cost wall time on "
                         "CPU; this is a collapse floor)")
    ap.add_argument("--allow-missing-speedup", action="store_true",
                    help="skip (rather than fail) speedup assertions when "
                         "the comparison fields are absent from the report")
    ap.add_argument("--require-metrics", default=None, metavar="DIR",
                    help="validate the observability artifacts "
                         "(metrics_<workload>.json + trace_<workload>"
                         ".jsonl) serve_bench exported into DIR")
    args = ap.parse_args()
    with open(args.report) as f:
        results = json.load(f)
    errors = check(results, args.min_speedup, args.min_paged_speedup,
                   args.min_fused_speedup, args.min_spec_speedup,
                   args.allow_missing_speedup)
    if args.require_metrics:
        errors += check_metrics(results, args.require_metrics)
    for e in errors:
        print(f"BENCH CHECK FAILED: {e}", file=sys.stderr)
    if errors:
        sys.exit(1)
    print(f"bench checks passed for {sorted(results)}"
          + (f" (+ metrics artifacts in {args.require_metrics})"
             if args.require_metrics else ""))


if __name__ == "__main__":
    main()
