"""Hypothesis property tests on the quantization system's invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, don't fail collection
from hypothesis import given, settings, strategies as st

from repro.core import packing, selection
from repro.core.swis import QuantConfig, fake_quant

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@given(st.integers(0, 255), st.integers(1, 7))
def test_single_value_error_bound(value, n_shifts):
    """SWIS nearest-candidate error is bounded by half the smallest
    representable gap above the value's magnitude scale."""
    mags = jnp.asarray([[float(value)]] * 4).reshape(1, 4)
    signs = jnp.ones((1, 4))
    out = selection.select_shifts(mags, signs, n_shifts=n_shifts)
    err = abs(float(out["qmags"][0, 0]) - value)
    # keeping the top n_shifts bits alone would give error < 2**(8-n)
    assert err < 2 ** (8 - n_shifts)


@given(st.integers(1, 8))
def test_representable_values_are_fixed_points(n_shifts):
    cand = selection.combo_candidates(n_shifts, 8, "swis")
    vals = np.unique(cand)[:16]
    mags = jnp.asarray(np.repeat(vals, 4).reshape(-1, 4), jnp.float32)
    signs = jnp.ones_like(mags)
    out = selection.select_shifts(mags, signs, n_shifts=n_shifts)
    np.testing.assert_array_equal(np.asarray(out["qmags"]),
                                  np.asarray(mags))


@given(st.integers(0, 10000), st.sampled_from([1, 2, 4, 8]),
       st.sampled_from([2, 3, 4]))
def test_sign_preservation_and_group_optimality(seed, group, n_shifts):
    rng = np.random.default_rng(seed)
    w = rng.normal(0, 0.1, (32, 4)).astype(np.float32)
    cfg = QuantConfig(n_shifts=n_shifts, group_size=group)
    q = np.asarray(fake_quant(jnp.asarray(w), cfg))
    # no sign flips (zero allowed)
    assert np.all((np.sign(q) == np.sign(w)) | (q == 0))
    scale = np.abs(w).max() / 255.0
    if group == 1:
        # solo groups: per-weight error bounded by the truncation fallback
        assert np.abs(q - w).max() <= scale * (2 ** (8 - n_shifts) + 1)
    # group-shared supports guarantee GROUP MSE++ optimality, not per-weight
    # bounds: SWIS is an argmin over a superset of the MSB-window combo
    # (same nearest-candidate assignment, same MSE++ metric, alpha=1).
    q_tr = np.asarray(fake_quant(jnp.asarray(w),
                                 QuantConfig(method="trunc",
                                             n_shifts=n_shifts,
                                             group_size=group,
                                             round_trunc=True)))

    def msepp(qq):
        e = (w - qq).reshape(-1, group, 4)
        return (e.sum(1) ** 2 + (e ** 2).sum(1)).sum()

    assert float(msepp(q)) <= float(msepp(q_tr)) + 1e-10


@given(st.integers(0, 1000), st.sampled_from([2, 3, 4, 5]))
def test_pack_roundtrip_random(seed, n_shifts):
    from repro.core.swis import quantize

    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(0, 0.05, (32, 4)).astype(np.float32))
    qw = quantize(w, QuantConfig(n_shifts=n_shifts, group_size=4))
    pw = packing.pack(qw)
    np.testing.assert_allclose(np.asarray(packing.unpack_dense(pw)),
                               np.asarray(qw.qweights), rtol=1e-6, atol=1e-9)


@given(st.sampled_from([2, 4, 8, 16]), st.sampled_from([1, 2, 3, 4, 5, 6]))
def test_compression_ratio_bounds(group, n_shifts):
    r_swis = packing.compression_ratio(group, n_shifts, "swis")
    r_c = packing.compression_ratio(group, n_shifts, "swis_c")
    assert r_c >= r_swis > 0
    # never better than the information floor of 1 sign + N mask bits
    assert r_swis <= 8.0 / (1 + n_shifts) + 1e-9


@given(st.integers(0, 500))
def test_data_pipeline_determinism(step):
    import repro.configs as C
    from repro.data import SyntheticPipeline

    cfg = C.get_smoke("smollm-135m")
    p1 = SyntheticPipeline(cfg, 16, 4, seed=7)
    p2 = SyntheticPipeline(cfg, 16, 4, seed=7)
    b1, b2 = p1.batch_at(step), p2.batch_at(step)
    for k in b1:
        np.testing.assert_array_equal(b1[k], b2[k])
    # labels are the next-token shift of tokens
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
