"""Offline filter scheduling (§4.3): two-phase heuristic invariants."""
import numpy as np

from repro.core import scheduling


def _costs(rng, c=32, levels=(1, 2, 3, 4, 5)):
    # synthetic per-column costs, strictly decreasing in n
    base = rng.random(c) * 10 + 1
    return {n: base * (0.5 ** n) for n in levels}


def test_average_hits_target(rng):
    costs = _costs(rng)
    for target in (2.0, 2.5, 3.0):
        sched = scheduling.schedule_layer(
            lambda n: costs[n], target, levels=[1, 2, 3, 4, 5], sa_cols=8)
        assert abs(sched.effective_shifts - target) < 1e-9


def test_groups_uniform_and_nondecreasing(rng):
    costs = _costs(rng)
    sched = scheduling.schedule_layer(
        lambda n: costs[n], 2.5, levels=[1, 2, 3, 4, 5], sa_cols=8)
    gs = sched.group_shifts
    assert list(gs) == sorted(gs)
    # co-scheduled columns share a shift count
    for g in range(len(gs)):
        cols = sched.order[g * 8:(g + 1) * 8]
        assert len(set(sched.col_shifts[cols])) == 1


def test_scheduling_beats_uniform(rng):
    # heterogeneous sensitivity: scheduling at avg 3 must beat uniform 3
    c = 32
    sens = np.concatenate([np.full(16, 0.1), np.full(16, 10.0)])
    costs = {n: sens * (0.5 ** n) for n in (1, 2, 3, 4, 5)}
    sched = scheduling.schedule_layer(
        lambda n: costs[n], 3.0, levels=[1, 2, 3, 4, 5], sa_cols=8)
    assert sched.total_cost <= costs[3].sum() + 1e-9


def test_double_shift_levels(rng):
    costs = {n: _costs(rng, levels=(2, 4, 6))[n] for n in (2, 4, 6)}
    sched = scheduling.schedule_layer(
        lambda n: costs[n], 3.0, levels=[2, 4, 6], sa_cols=8,
        double_shift=True)
    assert set(np.unique(sched.col_shifts)) <= {2, 4, 6}
    assert abs(sched.effective_shifts - 3.0) < 1e-9
