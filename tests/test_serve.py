"""Serve-engine parity: the continuous-batching engine must reproduce the
legacy static-batch DecodeEngine token-for-token (greedy AND
seeded-temperature — both engines share the per-row keyed sampler), stay
deterministic under staggered arrival, and recycle slots correctly when
the queue exceeds capacity."""
import functools

import jax
import numpy as np
import pytest

import repro.configs as C
from repro.core.swis import QuantConfig
from repro.models import params as pp
from repro.models.model import Model
from repro.serve import (ContinuousBatchingEngine, DecodeEngine,
                         EngineConfig, SamplingParams)

MAX_LEN = 48
QCFG = QuantConfig(method="swis", n_shifts=4, group_size=4)


@functools.lru_cache(maxsize=1)
def _setup():
    cfg = C.get_smoke("smollm-135m").replace(compute_dtype="float32")
    params = pp.init_params(Model(cfg).build(), jax.random.key(0))
    return cfg, params


def _prompts(rng, b, s0):
    cfg, _ = _setup()
    return rng.integers(0, cfg.vocab, (b, s0)).astype(np.int32)


@pytest.mark.parametrize("packed", [False, True])
@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_continuous_matches_legacy(rng, packed, temperature):
    cfg, params = _setup()
    prompt = _prompts(rng, 3, 8)
    legacy = DecodeEngine(cfg, params, max_len=MAX_LEN, batch=3,
                          packed=packed, quant_cfg=QCFG)
    cont = ContinuousBatchingEngine(cfg, params,
                                    config=EngineConfig(max_len=MAX_LEN,
                                                        n_slots=3,
            packed=packed, quant_cfg=QCFG))
    want = legacy.generate(prompt, 10, temperature=temperature, seed=7)
    got = cont.generate(prompt, 10, temperature=temperature, seed=7)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("temperature", [0.0, 0.7])
def test_staggered_arrival_is_lockstep_consistent(rng, temperature):
    """Request B joining while A is mid-decode must not change either
    request's tokens vs submitting both up front."""
    cfg, params = _setup()
    pa = _prompts(rng, 1, 5)[0]
    pb = _prompts(rng, 1, 9)[0]

    def run(stagger_b):
        eng = ContinuousBatchingEngine(cfg, params,
                                       config=EngineConfig(max_len=MAX_LEN,
                                                           n_slots=2))
        out = {}
        ra = eng.submit(pa, SamplingParams(max_tokens=10,
                                           temperature=temperature, seed=1))
        rb = None
        if not stagger_b:
            rb = eng.submit(pb, SamplingParams(max_tokens=6,
                                               temperature=temperature,
                    seed=2))
        for _ in range(3):  # A decodes several tokens first
            for f in eng.step():
                out[f.rid] = f.tokens
        if stagger_b:
            rb = eng.submit(pb, SamplingParams(max_tokens=6,
                                               temperature=temperature,
                    seed=2))
        for rid, full in eng.drain().items():
            s0 = len(pa) if rid == ra else len(pb)
            out[rid] = full[s0:]
        return out[ra], out[rb]

    a_lock, b_lock = run(stagger_b=False)
    a_stag, b_stag = run(stagger_b=True)
    np.testing.assert_array_equal(a_stag, a_lock)
    np.testing.assert_array_equal(b_stag, b_lock)


def test_queue_beyond_capacity_recycles_slots(rng):
    """5 mixed-length requests through 2 slots: every request's tokens must
    match a solo run (slot recycling and eviction are invisible)."""
    cfg, params = _setup()
    lens = (4, 6, 6, 9, 5)
    prompts = [_prompts(rng, 1, n)[0] for n in lens]
    eng = ContinuousBatchingEngine(cfg, params,
                                   config=EngineConfig(max_len=MAX_LEN,
                                                       n_slots=2))
    rids = [eng.submit(p, SamplingParams(max_tokens=7, seed=i)) for i,
            p in enumerate(prompts)]
    out = eng.drain()
    assert sorted(out) == sorted(rids)
    for i, (p, rid) in enumerate(zip(prompts, rids)):
        solo = ContinuousBatchingEngine(cfg, params,
                                        config=EngineConfig(max_len=MAX_LEN,
                                                            n_slots=2))
        srid = solo.submit(p, SamplingParams(max_tokens=7, seed=i))
        want = solo.drain()[srid]
        np.testing.assert_array_equal(out[rid], want)
        assert out[rid].shape == (len(p) + 7,)


def test_submit_rejects_overflow(rng):
    cfg, params = _setup()
    eng = ContinuousBatchingEngine(cfg, params, config=EngineConfig(max_len=16,
                                                                    n_slots=1))
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(_prompts(rng, 1, 10)[0], SamplingParams(max_tokens=10))


def test_generate_more_requests_than_slots(rng):
    """The compat wrapper also continuous-batches: B > n_slots works (the
    legacy engine could not do this at all) and stays per-row exact vs a
    wide-slot run."""
    cfg, params = _setup()
    prompt = _prompts(rng, 4, 6)
    wide = ContinuousBatchingEngine(cfg, params,
                                    config=EngineConfig(max_len=MAX_LEN,
                                                        n_slots=4))
    narrow = ContinuousBatchingEngine(cfg, params,
                                      config=EngineConfig(max_len=MAX_LEN,
                                                          n_slots=2))
    want = wide.generate(prompt, 6, temperature=0.5, seed=3)
    got = narrow.generate(prompt, 6, temperature=0.5, seed=3)
    np.testing.assert_array_equal(got, want)
