"""Bit-plane packing + compression math (paper §3.3, Fig. 5)."""
import jax.numpy as jnp
import numpy as np

from repro.core import packing


def test_pack_unpack_bits(rng):
    bits = jnp.asarray((rng.random((128, 5)) < 0.5).astype(np.uint8))
    words = packing.pack_bits_u32(bits)
    assert words.shape == (4, 5) and words.dtype == jnp.uint32
    rec = packing.unpack_bits_u32(words)
    np.testing.assert_array_equal(np.asarray(rec), np.asarray(bits))


def test_compression_formula_anchor_points():
    # paper: close to 3.7x at large groups / aggressive shifts
    assert abs(packing.compression_ratio(16, 1) - 3.66) < 0.01
    # paper §3.3: group 4 spans ~1.1x-2.9x; SWIS breaks even at N=4
    assert abs(packing.compression_ratio(4, 4) - 1.0) < 1e-9
    assert 1.1 < packing.compression_ratio(4, 5, "swis_c") < 1.3
    assert abs(packing.compression_ratio(4, 1, "swis") - 2.91) < 0.02
    assert abs(packing.compression_ratio(4, 1, "swis_c") - 2.91) < 0.02
    assert packing.compression_ratio(4, 3, "swis_c") > \
        packing.compression_ratio(4, 3, "swis")


def test_stored_bits_matches_formula(rng):
    from repro.core.swis import QuantConfig, quantize

    w = jnp.asarray(rng.normal(0, 0.05, (64, 8)).astype(np.float32))
    for method in ("swis", "swis_c"):
        cfg = QuantConfig(method=method, n_shifts=3, group_size=4)
        pw = packing.pack(quantize(w, cfg))
        ratio = (64 * 8 * 8) / pw.stored_bits
        assert abs(ratio - packing.compression_ratio(4, 3, method)) < 1e-9


def test_dpred_lossless_but_weaker(rng):
    # DPRed on realistic (bell-shaped) 8-bit magnitudes compresses less than
    # SWIS at iso group size (paper Fig. 5 discussion)
    mags = np.abs(rng.normal(0, 30, (4096, 16))).clip(0, 255).round()
    for g in (4, 8, 16):
        d = packing.dpred_compression(mags, g)
        assert 1.0 < d < packing.compression_ratio(g, 2, "swis_c") + 1.2
