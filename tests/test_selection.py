"""Shift-selection enumeration (§4.1): exactness vs brute force + invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import selection


@pytest.mark.parametrize("variant", ["swis", "swis_c", "trunc"])
@pytest.mark.parametrize("n_shifts", [2, 3, 4])
def test_matches_bruteforce(rng, variant, n_shifts):
    mags = rng.integers(0, 256, (48, 4)).astype(np.float32)
    signs = np.where(rng.random((48, 4)) < 0.5, -1.0, 1.0).astype(np.float32)
    fast = selection.select_shifts(jnp.asarray(mags), jnp.asarray(signs),
                                   n_shifts=n_shifts, variant=variant)
    slow = selection.select_shifts_bruteforce(mags, signs, n_shifts=n_shifts,
                                              variant=variant)
    np.testing.assert_allclose(np.asarray(fast["cost"]), slow["cost"],
                               rtol=1e-5)


@pytest.mark.parametrize("n_shifts", [2, 3, 4, 5])
def test_masks_reconstruct_qmags(rng, n_shifts):
    mags = rng.integers(0, 256, (64, 4)).astype(np.float32)
    signs = np.ones((64, 4), np.float32)
    out = selection.select_shifts(jnp.asarray(mags), jnp.asarray(signs),
                                  n_shifts=n_shifts)
    rec = ((np.asarray(out["masks"])[:, :, None]
            >> np.arange(n_shifts)[None, None, :]) & 1)
    rec = (rec * 2.0 ** np.asarray(out["shifts"])[:, None, :]).sum(-1)
    np.testing.assert_array_equal(rec, np.asarray(out["qmags"]))


def test_cost_monotone_in_shifts(rng):
    mags = rng.integers(0, 256, (128, 4)).astype(np.float32)
    signs = np.ones((128, 4), np.float32)
    prev = None
    for n in (1, 2, 3, 4, 5, 6):
        cost = float(np.sum(np.asarray(selection.select_shifts(
            jnp.asarray(mags), jnp.asarray(signs), n_shifts=n)["cost"])))
        if prev is not None:
            assert cost <= prev + 1e-6
        prev = cost


def test_variant_ordering(rng):
    mags = rng.integers(0, 256, (256, 4)).astype(np.float32)
    signs = np.ones((256, 4), np.float32)
    for n in (2, 3, 4):
        costs = {}
        for v in ("swis", "swis_c", "trunc"):
            costs[v] = float(np.sum(np.asarray(selection.select_shifts(
                jnp.asarray(mags), jnp.asarray(signs), n_shifts=n,
                variant=v)["cost"])))
        assert costs["swis"] <= costs["swis_c"] + 1e-6
        assert costs["swis_c"] <= costs["trunc"] + 1e-6


def test_eight_shifts_lossless(rng):
    mags = rng.integers(0, 256, (32, 4)).astype(np.float32)
    signs = np.ones((32, 4), np.float32)
    out = selection.select_shifts(jnp.asarray(mags), jnp.asarray(signs),
                                  n_shifts=8)
    np.testing.assert_array_equal(np.asarray(out["qmags"]), mags)
    assert float(np.max(np.asarray(out["cost"]))) == 0.0


def test_quantize_grouped_layout(rng):
    mags = rng.integers(0, 256, (16, 3)).astype(np.float32)
    signs = np.ones((16, 3), np.float32)
    out = selection.quantize_grouped(jnp.asarray(mags), jnp.asarray(signs),
                                     n_shifts=3, group_size=4)
    assert out["qmags"].shape == (16, 3)
    assert out["shifts"].shape == (4, 3, 3)
    # group (0, col 0) must share a support vector: check all members'
    # reconstructions only use those bit positions
    sh = np.asarray(out["shifts"])[0, 0]
    q = np.asarray(out["qmags"])[:4, 0].astype(np.int64)
    allowed = np.zeros(8, bool)
    allowed[sh] = True
    for v in q:
        bits = np.nonzero((v >> np.arange(8)) & 1)[0]
        assert all(allowed[b] for b in bits)


def test_alpha_reduces_signed_drift(rng):
    mags = rng.integers(0, 256, (512, 8)).astype(np.float32)
    signs = np.where(rng.random((512, 8)) < 0.5, -1.0, 1.0).astype(np.float32)
    drift = {}
    for alpha in (0.0, 4.0):
        out = selection.select_shifts(jnp.asarray(mags), jnp.asarray(signs),
                                      n_shifts=2, alpha=alpha)
        err = (mags - np.asarray(out["qmags"])) * signs
        drift[alpha] = float(np.abs(err.sum(-1)).mean())
    assert drift[4.0] <= drift[0.0] + 1e-6
