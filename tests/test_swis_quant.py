"""High-level quantize / fake_quant APIs: Table-1 orderings + scheduling."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import packing
from repro.core.swis import QuantConfig, act_truncate, fake_quant, quantize, rmse


@pytest.fixture
def weights(rng):
    return jnp.asarray(rng.normal(0, 0.05, (128, 64)).astype(np.float32))


def test_table1_rmse_ordering(weights):
    for n in (2, 3, 4, 5):
        r = {}
        for m in ("swis", "swis_c", "trunc"):
            q = fake_quant(weights, QuantConfig(method=m, n_shifts=n,
                                                group_size=4))
            r[m] = float(rmse(weights, q))
        assert r["swis"] <= r["swis_c"] + 1e-9 <= r["trunc"] + 1e-6
        # the paper's headline: floor-truncation is several x worse
        assert r["trunc"] / r["swis"] > 1.5


def test_rmse_grows_with_group_size(weights):
    prev = None
    for g in (1, 2, 4, 8, 16):
        q = fake_quant(weights, QuantConfig(n_shifts=3, group_size=g))
        cur = float(rmse(weights, q))
        if prev is not None:
            assert cur >= prev - 1e-7
        prev = cur


def test_fractional_shifts_interpolate(weights):
    r = {n: float(rmse(weights, fake_quant(
        weights, QuantConfig(n_shifts=n, group_size=4))))
        for n in (2, 2.5, 3)}
    assert r[3] <= r[2.5] <= r[2]


def test_double_shift_scheduling(weights):
    # DS with target 3 mixes 2- and 4-shift columns
    q = quantize(weights, QuantConfig(n_shifts=3, group_size=4,
                                      double_shift=True))
    cols = np.asarray(q.col_shifts)
    assert set(np.unique(cols)) <= {2, 4}
    assert abs(cols.mean() - 3.0) < 0.51


def test_requantization_stable(weights):
    # Exact idempotence does not hold (the per-tensor scale re-derives from
    # the quantized max), but double quantization must not degrade the
    # approximation of the original weights.
    cfg = QuantConfig(n_shifts=3, group_size=4)
    q1 = fake_quant(weights, cfg)
    q2 = fake_quant(q1, cfg)
    assert float(rmse(weights, q2)) < 1.6 * float(rmse(weights, q1))


def test_per_channel_improves(weights):
    # scale one column up so per-tensor scale hurts it
    w = np.asarray(weights).copy()
    w[:, 0] *= 10
    wj = jnp.asarray(w)
    r_pt = float(rmse(wj, fake_quant(wj, QuantConfig(n_shifts=3, group_size=4,
                                                     per_channel=False))))
    r_pc = float(rmse(wj, fake_quant(wj, QuantConfig(n_shifts=3, group_size=4,
                                                     per_channel=True))))
    assert r_pc < r_pt


def test_act_truncate():
    a = jnp.asarray(np.linspace(-1, 1, 1000, dtype=np.float32))
    scale = 1.0 / 255.0  # 8-bit round-to-nearest grid before bit dropping
    for n in (2, 4, 6):
        t = act_truncate(a, n)
        # magnitudes shrink (floor toward zero) up to the rounding epsilon
        assert float(jnp.max(jnp.abs(t) - jnp.abs(a))) <= scale / 2 + 1e-6
    # more bits => smaller error
    errs = [float(jnp.mean((act_truncate(a, n) - a) ** 2)) for n in (2, 4, 6, 8)]
    assert errs == sorted(errs, reverse=True)


def test_quantize_metadata_roundtrip(weights):
    for method in ("swis", "swis_c", "trunc"):
        qw = quantize(weights, QuantConfig(method=method, n_shifts=3,
                                           group_size=4))
        pw = packing.pack(qw)
        dense = packing.unpack_dense(pw)
        np.testing.assert_allclose(np.asarray(dense),
                                   np.asarray(qw.qweights), rtol=1e-6,
                                   atol=1e-9)
