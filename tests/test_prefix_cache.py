"""Prefix-cache invariants and engine-level parity.

Trie/pool level (plus hypothesis property tests when available):
  * a matched prefix is always a chain of committed blocks from the root;
  * refcounts never go negative; eviction never drops a referenced block
    (or a non-leaf, which a later match would then miss).

Engine level: with the prefix cache ON, output must be token-exact vs the
cache-OFF path — shared-prefix workloads, staggered arrival, and eviction
pressure included — while ``prefix_stats()`` reports real hits/savings.
"""
import functools

import jax
import numpy as np
import pytest

import repro.configs as C
from repro.models import params as pp
from repro.models.model import Model
from repro.serve import (BlockPool, ContinuousBatchingEngine, DecodeEngine,
                         EngineConfig, SamplingParams,
                         RadixPrefixCache)

MAX_LEN = 48
BS = 8  # block size used throughout


# ---------------------------------------------------------------------------
# BlockPool / RadixPrefixCache (pure host-side bookkeeping)
# ---------------------------------------------------------------------------


def _toks(rng, n):
    return rng.integers(0, 512, (n,)).astype(np.int32)


def test_match_is_committed_prefix(rng):
    pool = BlockPool(32, BS)
    trie = RadixPrefixCache(pool)
    seq = _toks(rng, 3 * BS + 5)  # 3 full blocks + remainder
    ids = pool.alloc(3)
    pool.incref(ids)
    trie.commit(seq, ids)
    assert trie.match(seq) == ids
    assert trie.match(seq[: 2 * BS + 3]) == ids[:2]  # partial block ignored
    assert trie.match(seq, max_blocks=1) == ids[:1]
    # diverging sequence matches only the shared block-aligned prefix
    other = np.concatenate([seq[:BS], _toks(rng, 2 * BS)])
    assert trie.match(other) == ids[:1]
    assert trie.match(_toks(rng, 4 * BS)) == []


def test_block0_reserved():
    """Block 0 is the trash block: never allocated, refcount pinned, and
    free([0]) must raise — table entry 0 means "invalid" to the paged
    decode kernel, so it can never re-enter circulation as live storage."""
    pool = BlockPool(8, BS)
    assert pool.refcount[0] == 1
    assert pool.n_free() == 7
    ids = pool.alloc(7)  # drain the pool completely
    assert ids is not None and 0 not in ids
    assert pool.alloc(1) is None
    with pytest.raises(RuntimeError, match="referenced"):
        pool.free([0])


def test_refcounts_never_negative(rng):
    pool = BlockPool(8, BS)
    ids = pool.alloc(2)
    pool.incref(ids)
    pool.decref(ids)
    with pytest.raises(RuntimeError, match="negative"):
        pool.decref(ids)


def test_free_referenced_block_rejected():
    pool = BlockPool(8, BS)
    (b,) = pool.alloc(1)
    pool.incref([b])
    with pytest.raises(RuntimeError, match="referenced"):
        pool.free([b])


def test_eviction_skips_referenced_and_interior(rng):
    pool = BlockPool(32, BS)
    trie = RadixPrefixCache(pool)
    seq = _toks(rng, 3 * BS)
    ids = pool.alloc(3)
    pool.incref(ids)
    trie.commit(seq, ids)
    # still slot-referenced: nothing is evictable
    assert trie.evict(3) == 0
    trie.release(ids)
    # unreferenced: evictable leaf-first, so one evict takes the deepest
    assert trie.evict(1) == 1
    assert trie.match(seq) == ids[:2]
    # evicting the rest clears the chain and returns blocks to the pool
    assert trie.evict(10) == 2
    assert trie.match(seq) == []
    assert pool.n_free() == 31  # all but the trash block


def test_lru_eviction_order(rng):
    pool = BlockPool(32, BS)
    trie = RadixPrefixCache(pool)
    a, b = _toks(rng, BS), _toks(rng, BS)
    (ia,) = pool.alloc(1)
    (ib,) = pool.alloc(1)
    pool.incref([ia])
    pool.incref([ib])
    trie.commit(a, [ia])
    trie.commit(b, [ib])
    trie.release([ia])
    trie.release([ib])
    trie.match(a)  # refresh a -> b is now LRU
    assert trie.evict(1) == 1
    assert trie.match(a) == [ia] and trie.match(b) == []


def test_commit_keeps_existing_block(rng):
    pool = BlockPool(32, BS)
    trie = RadixPrefixCache(pool)
    seq = _toks(rng, BS)
    (ia,) = pool.alloc(1)
    pool.incref([ia])
    trie.commit(seq, [ia])
    # a concurrent request that missed holds its own duplicate block
    (ib,) = pool.alloc(1)
    pool.incref([ib])
    trie.commit(seq, [ib])  # chunk present: existing block ia wins
    assert trie.match(seq) == [ia]
    trie.release([ib])  # duplicate is not committed -> freed
    assert ib in pool._free
    trie.release([ia])
    assert ia not in pool._free  # committed: cached, not freed


# ---------------------------------------------------------------------------
# Engine-level parity: prefix cache ON must be token-exact vs OFF
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=1)
def _setup():
    cfg = C.get_smoke("smollm-135m").replace(compute_dtype="float32")
    params = pp.init_params(Model(cfg).build(), jax.random.key(0))
    return cfg, params


def _shared_prefix_prompts(rng, n, n_sys=2, sys_len=17):
    cfg, _ = _setup()
    sys_p = [rng.integers(0, cfg.vocab, (sys_len,)).astype(np.int32)
             for _ in range(n_sys)]
    return [np.concatenate([sys_p[i % n_sys],
                            rng.integers(0, cfg.vocab,
                                         (3 + i % 5,)).astype(np.int32)])
            for i in range(n)]


def _run(prompts, n_tok, temperature, prefix_cache, stagger=0, n_slots=3,
         **kw):
    cfg, params = _setup()
    eng = ContinuousBatchingEngine(cfg, params,
                                   config=EngineConfig(max_len=MAX_LEN,
                                                       n_slots=n_slots,
            prefix_cache=prefix_cache, block_size=BS, **kw))
    rids = []
    for i, p in enumerate(prompts):
        if stagger and i and i % stagger == 0:
            eng.step()  # admissions interleave with in-flight decode
        rids.append(eng.submit(p, SamplingParams(max_tokens=n_tok,
                                                 temperature=temperature,
                seed=i)))
    out = eng.drain()
    return eng, [out[r] for r in rids]


@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_shared_prefix_token_exact_and_hits(rng, temperature):
    prompts = _shared_prefix_prompts(rng, 6)
    on, got = _run(prompts, 8, temperature, True)
    _, want = _run(prompts, 8, temperature, False)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)
    stats = on.prefix_stats()
    assert stats["enabled"] and stats["hit_rate"] > 0
    assert stats["saved_tokens"] > 0
    assert stats["prefill_tokens"] < sum(len(p) for p in prompts)


def test_staggered_arrival_parity(rng):
    prompts = _shared_prefix_prompts(rng, 7)
    on, got = _run(prompts, 6, 0.7, True, stagger=2)
    _, want = _run(prompts, 6, 0.7, False, stagger=2)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)
    assert on.prefix_stats()["hit_rate"] > 0


def test_eviction_pressure_parity(rng):
    # almost no spare arena: committed chains are evicted under pressure,
    # and that must stay invisible in the tokens
    prompts = [rng.integers(0, 512, (int(rng.integers(9, 20)),))
               .astype(np.int32) for _ in range(8)]
    on, got = _run(prompts, 6, 0.6, True, n_slots=2, n_cache_blocks=3)
    _, want = _run(prompts, 6, 0.6, False, n_slots=2)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)
    assert on.prefix_stats()["evictions"] > 0


def test_repeat_prompt_skips_prefill_compute(rng):
    """A repeated prompt must re-reference committed blocks: the second
    pass prefills only the uncached suffix tokens."""
    cfg, params = _setup()
    eng = ContinuousBatchingEngine(cfg, params,
                                   config=EngineConfig(max_len=MAX_LEN,
                                                       n_slots=1,
            prefix_cache=True, block_size=BS))
    p = rng.integers(0, cfg.vocab, (2 * BS + 3,)).astype(np.int32)
    r1 = eng.submit(p, SamplingParams(max_tokens=4, seed=0))
    first = eng.drain()[r1]
    t0 = eng.prefix_stats()["prefill_tokens"]
    r2 = eng.submit(p, SamplingParams(max_tokens=4, seed=0))
    second = eng.drain()[r2]
    np.testing.assert_array_equal(first, second)
    stats = eng.prefix_stats()
    # 2 full blocks cached -> only len(p) - 2*BS suffix tokens computed
    assert stats["prefill_tokens"] - t0 == len(p) - 2 * BS
    assert stats["saved_tokens"] == 2 * BS


def test_unadmit_under_pool_pressure_leaks_no_refcounts(rng):
    """Regression: a failed admission increfs the matched prefix chain and
    must roll it back (``scheduler.unadmit`` + ``prefix_cache.release``) —
    a leak here strands arena blocks with phantom references forever.
    Starve the pool with an external pin, watch admissions fail and
    requeue, then unpin, drain, and check every non-reserved block is
    either free or committed with refcount zero."""
    cfg, params = _setup()
    eng = ContinuousBatchingEngine(cfg, params,
                                   config=EngineConfig(max_len=MAX_LEN,
                                                       n_slots=2,
            prefix_cache=True, block_size=BS, prefill_chunk=BS))
    pool = eng.prefix_cache.pool
    base = rng.integers(0, cfg.vocab, (2 * BS + 3,)).astype(np.int32)
    first = eng.submit(base, SamplingParams(max_tokens=5, seed=0))
    assert first in eng.drain()  # commits base's full blocks into the trie
    matched_blocks = eng.prefix_cache.match(base)
    assert len(matched_blocks) == 2

    pinned = pool.alloc(pool.n_free())  # external pin: pool is starved
    pool.incref(pinned)
    prompts = [np.concatenate([base, rng.integers(0, cfg.vocab, (10 + i,))
                               .astype(np.int32)]) for i in range(2)]
    rids = [eng.submit(p, SamplingParams(max_tokens=6, seed=1 + i)) for i,
            p in enumerate(prompts)]
    for _ in range(3):
        eng.step()
    # both admissions failed mid-PREFILLING and went back to the queue,
    # and the matched blocks' speculative references were rolled back
    assert len(eng.scheduler.queue) == 2
    for b in matched_blocks:
        assert pool.refcount[b] == 0

    pool.decref(pinned)
    pool.free(pinned)
    out = eng.drain()
    assert sorted(out) == sorted(set(out) | set(rids))
    assert pool.refcount[0] == 1  # trash block stays pinned
    np.testing.assert_array_equal(pool.refcount[1:], 0)
    committed = {b for b in range(1, pool.n_blocks)
                 if eng.prefix_cache.is_committed(b)}
    free = set(pool._free)
    assert free.isdisjoint(committed)
    assert free | committed == set(range(1, pool.n_blocks))


def test_fresh_memo_is_bounded(rng):
    cfg, params = _setup()
    eng = ContinuousBatchingEngine(cfg, params,
                                   config=EngineConfig(max_len=MAX_LEN,
                                                       n_slots=2,
            prefix_cache=True, bucket_prompts=True))
    for i, L in enumerate(range(4, 34, 2)):
        eng.submit(rng.integers(0, cfg.vocab, (L,)).astype(np.int32),
                   SamplingParams(max_tokens=2, seed=i))
    eng.drain()
    assert len(eng.cache._fresh) <= 8


@pytest.mark.parametrize("arch", ["mamba2-2.7b", "recurrentgemma-2b"])
def test_recurrent_family_falls_back_contiguous(rng, arch):
    """Families with stateful / window-truncated caches must not get block
    mode or bucket padding (pad tokens would corrupt recurrent state), and
    must stay token-exact vs the static engine through the fallback."""
    cfg = C.get_smoke(arch).replace(compute_dtype="float32")
    params = pp.init_params(Model(cfg).build(), jax.random.key(0))
    eng = ContinuousBatchingEngine(cfg, params, config=EngineConfig(max_len=32,
                                                                    n_slots=2,
            prefix_cache=True))
    assert eng.prefix_cache is None and not eng.bucket_prompts
    legacy = DecodeEngine(cfg, params, max_len=32, batch=2)
    prompt = rng.integers(0, cfg.vocab, (2, 7)).astype(np.int32)
    np.testing.assert_array_equal(
        eng.generate(prompt, 6, temperature=0.7, seed=3),
        legacy.generate(prompt, 6, temperature=0.7, seed=3))


def test_prefix_stats_disabled_fallback(rng):
    cfg, params = _setup()
    eng = ContinuousBatchingEngine(cfg, params,
                                   config=EngineConfig(max_len=MAX_LEN,
                                                       n_slots=2,
            prefix_cache=False))
    assert eng.prefix_stats() == {"enabled": False, "prefill_tokens": 0,
                                  "saved_tokens": 0, "prefill_chunk": None,
                                  "prefill_chunk_steps": 0}
