"""Per-architecture smoke tests (assignment deliverable f): reduced configs,
one forward/train step on CPU, output shapes + no NaNs; decode consistency;
full-config parameter counts against published sizes."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import params as pp
from repro.models.model import Model

ARCHS = list(C.ARCH_IDS)


def _batch(cfg, rng, b=2, s=16):
    batch = {}
    if cfg.family == "encoder":
        batch["frames"] = jnp.asarray(
            rng.normal(0, 1, (b, s, cfg.d_model)), jnp.float32)
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)),
                                  jnp.int32)
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(0, 1, (b, cfg.vlm.n_patches, cfg.vlm.vision_dim)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(rng, arch):
    cfg = C.get_smoke(arch)
    m = Model(cfg)
    params = pp.init_params(m.build(), jax.random.key(0))
    batch = _batch(cfg, rng)
    logits, _, _ = m.apply(params, batch)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))
    # one gradient step
    loss, grads = jax.value_and_grad(lambda p: m.loss(p, batch)[0])(params)
    assert np.isfinite(float(loss))
    gn = max(float(jnp.max(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn)


@pytest.mark.parametrize("arch", [a for a in ARCHS if a != "hubert-xlarge"])
def test_decode_matches_full_forward(rng, arch):
    cfg = C.get_smoke(arch).replace(compute_dtype="float32")
    if cfg.moe is not None:  # avoid capacity-drop divergence (see moe.py)
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=8.0))
    m = Model(cfg)
    params = pp.init_params(m.build(), jax.random.key(0))
    b, s = 2, 16
    batch = _batch(cfg, rng, b, s)
    batch.pop("labels")
    logits_full, _, _ = m.apply(params, batch)
    cache = pp.init_params(m.build_cache(b, s, jnp.float32), jax.random.key(0))
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, : s - 1]
    _, cache = m.prefill(params, pre, cache)
    dec = {"tokens": batch["tokens"][:, s - 1:]}
    if "patches" in batch:
        dec["patches"] = batch["patches"]
    logits_dec, _, _ = m.apply(params, dec, cache=cache,
                               cache_index=jnp.int32(s - 1))
    err = float(jnp.max(jnp.abs(logits_dec[:, -1] - logits_full[:, -1]))
                / (jnp.max(jnp.abs(logits_full[:, -1])) + 1e-9))
    assert err < 2e-3, err


PUBLISHED = {
    "qwen2-moe-a2.7b": 14.3e9, "dbrx-132b": 132e9,
    "recurrentgemma-2b": 2.7e9, "llama-3.2-vision-11b": 10.6e9,
    "mistral-large-123b": 123e9, "phi3-mini-3.8b": 3.8e9,
    "smollm-135m": 0.135e9, "deepseek-7b": 6.9e9, "mamba2-2.7b": 2.7e9,
    "hubert-xlarge": 0.96e9,
}


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_count(arch):
    cfg = C.get_config(arch)
    n = pp.count_params(Model(cfg).build())
    assert 0.9 < n / PUBLISHED[arch] < 1.12, (arch, n)


def test_qat_quantized_forward(rng):
    from benchmarks.common import quant_policy  # reuse policy builder

    cfg = C.get_smoke("phi3-mini-3.8b")
    cfg = cfg.replace(quant=dataclasses.replace(
        quant_policy("swis", 3), mode="qat"))
    m = Model(cfg)
    params = pp.init_params(m.build(), jax.random.key(0))
    batch = _batch(cfg, rng)
    loss, grads = jax.value_and_grad(lambda p: m.loss(p, batch)[0])(params)
    assert np.isfinite(float(loss))
    # STE: gradient must reach the latent weights of quantized layers
    g = grads["blocks"]["sub0_attn"]["mlp"]["wi"]["w"]
    assert float(jnp.max(jnp.abs(g))) > 0


def test_mamba_ssd_vs_naive(rng):
    from repro.models.ssm import ssd_chunked

    B, L, H, P, N = 2, 32, 3, 4, 8
    x = jnp.asarray(rng.normal(0, 1, (B, L, H, P)).astype(np.float32))
    dt = jnp.asarray(jax.nn.softplus(
        rng.normal(0, 1, (B, L, H))).astype(np.float32))
    a_neg = -jnp.exp(jnp.asarray(rng.normal(0, .5, (H,)).astype(np.float32)))
    bm = jnp.asarray(rng.normal(0, 1, (B, L, N)).astype(np.float32))
    cm = jnp.asarray(rng.normal(0, 1, (B, L, N)).astype(np.float32))
    s = np.zeros((B, H, P, N))
    ys = []
    for t in range(L):
        da = np.exp(np.asarray(dt[:, t, :]) * np.asarray(a_neg)[None, :])
        upd = np.einsum("bhp,bn->bhpn",
                        np.asarray(x[:, t] * dt[:, t, :, None]),
                        np.asarray(bm[:, t]))
        s = s * da[:, :, None, None] + upd
        ys.append(np.einsum("bhpn,bn->bhp", s, np.asarray(cm[:, t])))
    want = np.stack(ys, 1)
    for chunk in (8, 16, 32):
        got, endstate = ssd_chunked(x, dt, a_neg, bm, cm, chunk)
        np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4,
                                   atol=2e-4 * np.abs(want).max())
        np.testing.assert_allclose(np.asarray(endstate), s, rtol=2e-4,
                                   atol=2e-4 * np.abs(s).max())


def test_rglru_scan_vs_loop(rng):
    from repro.models.rglru import _rglru_scan

    B, L, W = 2, 24, 8
    log_a = jnp.asarray(-np.abs(rng.normal(0, 1, (B, L, W))).astype(np.float32))
    b = jnp.asarray(rng.normal(0, 1, (B, L, W)).astype(np.float32))
    h = _rglru_scan(log_a, b, None)
    ref = np.zeros((B, W))
    for t in range(L):
        ref = np.exp(np.asarray(log_a[:, t])) * ref + np.asarray(b[:, t])
    np.testing.assert_allclose(np.asarray(h[:, -1]), ref, rtol=1e-5,
                               atol=1e-6)
