"""Analytical perfmodel: paper-claim reproduction + monotonicity."""

from repro.perfmodel import NETWORKS, PE_LIBRARY, SystolicArray, simulate_network
from repro.perfmodel.evaluate import evaluate_table4, fig1_dram_ratio, headline_ratios


def _net(cfg_name, n_shifts, method, net="resnet18"):
    arr = SystolicArray(PE_LIBRARY[cfg_name])
    return simulate_network(arr, NETWORKS[net], n_shifts=n_shifts,
                            method=method)


def test_fewer_shifts_faster():
    prev = None
    for n in (6, 4, 3, 2):
        r = _net("swis_ss", n, "swis")
        if prev is not None:
            assert r["frames_per_s"] > prev["frames_per_s"]
            assert r["frames_per_j"] > prev["frames_per_j"]
        prev = r


def test_double_shift_faster_than_single():
    ss = _net("swis_ss", 4, "swis")
    ds = _net("swis_ds", 4, "swis")
    assert ds["frames_per_s"] > ss["frames_per_s"] * 1.5


def test_swis_c_better_compression_dram():
    s = _net("swis_ss", 3, "swis")
    c = _net("swis_c_ss", 3, "swis_c")
    assert c["wgt_dram_bytes"] < s["wgt_dram_bytes"]


def test_headline_claims_reproduced():
    h = headline_ratios()
    # paper: up to 6x speedup, up to 1.9x energy vs act-trunc bit-serial
    assert 4.5 <= h["max_speedup_vs_act_trunc"] <= 6.5
    assert 1.5 <= h["max_energy_ratio_vs_act_trunc"] <= 2.1
    # paper §3.3: up to 2.3x lower DRAM bandwidth vs 8-bit fixed
    assert 1.8 <= h["dram_reduction_vs_fixed8"] <= 2.6


def test_table4_fs_anchors():
    # F/s calibration against paper Table 4 (ResNet-18)
    paper_fs = {("swis_ss", "hi"): 28.6, ("swis_ds", "hi"): 42.9,
                ("act_trunc", "hi"): 12.2, ("fixed8", "hi"): 23.2,
                ("swis_ds", "lo"): 85.7}
    rows = {(r["config"], r["point"]): r for r in evaluate_table4()
            if r["network"] == "resnet18"}
    for key, want in paper_fs.items():
        got = rows[key]["frames_per_s"]
        assert abs(got - want) / want < 0.12, (key, got, want)


def test_fig1_weight_dominated_layers():
    ratios = [r for _, r in fig1_dram_ratio()]
    # paper: some layers have ~2 orders of magnitude more weight accesses
    assert max(ratios) > 50
    assert min(ratios) < 1  # early layers are activation-dominated


def test_mobilenet_depthwise_underutilization():
    # depthwise layers cost proportionally more on bit-serial (group waste)
    sw = _net("swis_ss", 3, "swis", "mobilenet_v2")
    fx = _net("fixed8", 8, "fixed8", "mobilenet_v2")
    sw_r = _net("swis_ss", 3, "swis", "resnet18")
    fx_r = _net("fixed8", 8, "fixed8", "resnet18")
    mob_speedup = sw["frames_per_s"] / fx["frames_per_s"]
    res_speedup = sw_r["frames_per_s"] / fx_r["frames_per_s"]
    assert mob_speedup < res_speedup
