"""Hypothesis property tests for the radix prefix cache (block pool +
trie): insert/match/evict invariants under random request lifecycles.
The ``requires_hypothesis`` marker keeps tier-1 collection green (and
import-free) without hypothesis; the deterministic invariant tests live
in ``tests/test_prefix_cache.py`` and the speculative-decode rollback
machine in ``tests/test_rollback_invariants.py``."""
import numpy as np
import pytest

from conftest import requires_hypothesis
from repro.serve import BlockPool, RadixPrefixCache

BS = 8


def _toks(rng, n):
    return rng.integers(0, 512, (n,)).astype(np.int32)


@pytest.mark.slow
@requires_hypothesis()
def test_random_walk_invariants():
    """Random interleavings of request lifecycles + evictions: a matched
    chain is always a root-linked committed chain whose node chunks equal
    the query's token blocks (and was committed by some earlier finish),
    refcounts stay consistent, and the free list never intersects live
    references."""
    import hypothesis as hyp
    from hypothesis import strategies as st

    @hyp.settings(max_examples=30, deadline=None)
    @hyp.given(st.data())
    def prop(data):
        pool = BlockPool(24, BS)
        trie = RadixPrefixCache(pool)
        rng = np.random.default_rng(data.draw(st.integers(0, 2 ** 31)))
        ever_committed = set()  # append-only: chunk-chain keys any finish made
        finished_seqs = []  # to derive shared-prefix queries from
        live = []  # (block_ids, seq) held by in-flight "requests"
        for _ in range(data.draw(st.integers(5, 40))):
            op = data.draw(st.sampled_from(["admit", "finish", "evict"]))
            if op == "admit":
                seq = _toks(rng, data.draw(st.integers(1, 3)) * BS)
                if data.draw(st.booleans()) and finished_seqs:
                    # extend a previously-finished sequence to force hits
                    base = finished_seqs[rng.integers(len(finished_seqs))]
                    seq = np.concatenate([base, seq])[: 3 * BS]
                matched = trie.match(seq)
                # invariants: the matched chain is committed, root-linked,
                # and keyed by exactly this query's token blocks
                parent = trie._root
                for j, blk in enumerate(matched):
                    node = trie._node_of_block[blk]
                    assert node.chunk == seq[j * BS: (j + 1) * BS].tobytes()
                    assert node.parent is parent
                    assert seq[: (j + 1) * BS].tobytes() in ever_committed
                    parent = node
                pool.incref(matched)
                own = len(seq) // BS - len(matched)
                if pool.n_free() < own:
                    trie.evict(own - pool.n_free())
                ids = pool.alloc(own)
                if ids is None:
                    trie.release(matched)
                    continue
                pool.incref(ids)
                live.append((matched + ids, seq))
            elif op == "finish" and live:
                blocks, seq = live.pop(rng.integers(len(live)))
                trie.commit(seq, blocks)
                for j in range(len(blocks)):
                    ever_committed.add(seq[: (j + 1) * BS].tobytes())
                finished_seqs.append(seq)
                trie.release(blocks)
            else:
                referenced = {b for blocks, _ in live for b in blocks}
                trie.evict(data.draw(st.integers(1, 4)))
                # eviction never drops a referenced block
                assert not referenced & set(pool._free)
            # global invariants
            referenced = {b for blocks, _ in live for b in blocks}
            assert not referenced & set(pool._free)
            assert not set(trie._node_of_block) & set(pool._free)
            # trash block 0: refcount pinned to 1, never on the free list,
            # never committed to the trie
            assert (pool.refcount[1:] >= 0).all()
            assert pool.refcount[0] == 1 and 0 not in pool._free
            assert 0 not in trie._node_of_block

    prop()
