"""Attention numerics: chunked online-softmax vs full softmax; windows; GQA."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import chunked_attention, full_attention


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(0, 1, shape).astype(np.float32))


@pytest.mark.parametrize("chunk", [3, 8, 16, 64])
@pytest.mark.parametrize("window", [None, 7])
@pytest.mark.parametrize("causal", [True, False])
def test_chunked_matches_full(rng, chunk, window, causal):
    b, sq, skv, h, hkv, dh = 2, 16, 16, 4, 2, 8
    q = _rand(rng, b, sq, h, dh)
    k = _rand(rng, b, skv, hkv, dh)
    v = _rand(rng, b, skv, hkv, dh)
    pos = jnp.arange(sq, dtype=jnp.int32)
    kv_pos = jnp.arange(skv, dtype=jnp.int32)
    want = full_attention(q, k, v, q_pos=pos, kv_pos=kv_pos, causal=causal,
                          window=window)
    got = chunked_attention(q, k, v, q_pos=pos, kv_pos=kv_pos, causal=causal,
                            window=window, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5,
                               atol=2e-5)


def test_padding_positions_masked(rng):
    # kv_pos = -1 entries must not contribute
    b, s, h, dh = 1, 8, 2, 4
    q = _rand(rng, b, s, h, dh)
    k = _rand(rng, b, s, h, dh)
    v = _rand(rng, b, s, h, dh)
    pos = jnp.arange(s, dtype=jnp.int32)
    kv_pos = pos.at[5:].set(-1)
    out = full_attention(q, k, v, q_pos=pos, kv_pos=kv_pos, causal=True,
                         window=None)
    k2 = k.at[:, 5:].set(1e3)  # poison masked slots; output must not change
    v2 = v.at[:, 5:].set(1e3)
    out2 = full_attention(q, k2, v2, q_pos=pos, kv_pos=kv_pos, causal=True,
                          window=None)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), atol=1e-6)


def test_gqa_head_grouping(rng):
    # 4 query heads on 2 kv heads == manually repeated kv with MHA
    b, s, dh = 1, 8, 4
    q = _rand(rng, b, s, 4, dh)
    k = _rand(rng, b, s, 2, dh)
    v = _rand(rng, b, s, 2, dh)
    pos = jnp.arange(s, dtype=jnp.int32)
    gqa = full_attention(q, k, v, q_pos=pos, kv_pos=pos, causal=True,
                         window=None)
    k_rep = jnp.repeat(k, 2, axis=2)
    v_rep = jnp.repeat(v, 2, axis=2)
    # repeat_interleave matches the (hkv, group) reshape convention
    mha = full_attention(q, k_rep, v_rep, q_pos=pos, kv_pos=pos, causal=True,
                         window=None)
    np.testing.assert_allclose(np.asarray(gqa), np.asarray(mha), rtol=1e-5,
                               atol=1e-6)
