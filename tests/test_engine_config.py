"""EngineConfig / SamplingParams: eager validation at construction (the
typed API's reason to exist — misconfiguration fails with an actionable
message before any model work, not steps deep into serving) and the
legacy loose-kwarg shims (deprecated but working, one release)."""
import dataclasses
import functools
import warnings

import jax
import numpy as np
import pytest

import repro.configs as C
from repro.models import params as pp
from repro.models.model import Model
from repro.serve import (ContinuousBatchingEngine, EngineConfig,
                         SamplingParams)


@functools.lru_cache(maxsize=1)
def _setup():
    cfg = C.get_smoke("smollm-135m").replace(compute_dtype="float32")
    params = pp.init_params(Model(cfg).build(), jax.random.key(0))
    return cfg, params


# -- EngineConfig validation -------------------------------------------

@pytest.mark.parametrize("field", ["max_len", "n_slots", "block_size",
                                   "prefill_backlog", "trace_capacity"])
def test_config_floors(field):
    with pytest.raises(ValueError, match=field):
        EngineConfig(**{field: 0})


def test_config_prefill_chunk_floor():
    with pytest.raises(ValueError, match="prefill_chunk"):
        EngineConfig(prefill_chunk=0)


def test_config_negative_cache_blocks():
    with pytest.raises(ValueError, match="n_cache_blocks"):
        EngineConfig(n_cache_blocks=-1)


def test_config_chunk_requires_prefix_cache():
    with pytest.raises(ValueError, match="prefill_chunk"):
        EngineConfig(prefill_chunk=16, prefix_cache=False)


def test_config_paged_requires_prefix_cache():
    with pytest.raises(ValueError, match="use_paged_kernel"):
        EngineConfig(use_paged_kernel=True, prefix_cache=False)


def test_config_fused_requires_chunk():
    with pytest.raises(ValueError, match="fused_step"):
        EngineConfig(fused_step=True)


def test_config_unknown_paged_impl_fails_eagerly():
    """The headline fix: a typo'd impl used to sail through construction
    and explode inside the first jitted decode step. Now it fails at
    EngineConfig() time and the message lists the valid impls."""
    with pytest.raises(ValueError) as exc:
        EngineConfig(use_paged_kernel=True, paged_impl="palas")
    msg = str(exc.value)
    for valid in ("pallas", "pallas_interpret", "xla"):
        assert valid in msg


def test_config_paged_impl_without_kernel():
    with pytest.raises(ValueError, match="use_paged_kernel"):
        EngineConfig(paged_impl="xla")


def test_config_frozen():
    cfg = EngineConfig()
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.max_len = 512


def test_config_valid_combinations_construct():
    EngineConfig(prefill_chunk=16, fused_step=True)
    EngineConfig(use_paged_kernel=True, paged_impl="pallas_interpret")
    EngineConfig(prefix_cache=False)


# -- SamplingParams validation -----------------------------------------

def test_sampling_negative_budget():
    with pytest.raises(ValueError, match="max_tokens"):
        SamplingParams(max_tokens=-1)


def test_sampling_zero_budget_allowed():
    assert SamplingParams(max_tokens=0).max_tokens == 0


def test_sampling_negative_temperature():
    with pytest.raises(ValueError, match="temperature"):
        SamplingParams(max_tokens=4, temperature=-0.1)


def test_sampling_seed_key_exclusive():
    with pytest.raises(ValueError, match="mutually exclusive"):
        SamplingParams(max_tokens=4, seed=0, key=jax.random.key(0))


# -- engine construction shims -----------------------------------------

def test_legacy_kwargs_warn_and_work(rng):
    cfg, params = _setup()
    with pytest.warns(DeprecationWarning, match="EngineConfig"):
        eng = ContinuousBatchingEngine(cfg, params, max_len=32, n_slots=2)
    assert eng.max_len == 32 and eng.n_slots == 2
    assert eng.config == EngineConfig(max_len=32, n_slots=2)


def test_config_and_legacy_kwargs_conflict():
    cfg, params = _setup()
    with pytest.raises(TypeError, match="not both"):
        ContinuousBatchingEngine(cfg, params, config=EngineConfig(),
                                 max_len=32)


def test_unknown_legacy_kwarg_lists_fields():
    cfg, params = _setup()
    with pytest.raises(TypeError) as exc:
        ContinuousBatchingEngine(cfg, params, maxlen=32)
    msg = str(exc.value)
    assert "maxlen" in msg and "max_len" in msg


def test_legacy_kwargs_still_validated():
    cfg, params = _setup()
    with pytest.raises(ValueError, match="fused_step"), \
            warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        ContinuousBatchingEngine(cfg, params, fused_step=True)


def test_non_config_positional_rejected():
    cfg, params = _setup()
    with pytest.raises(TypeError, match="EngineConfig"):
        ContinuousBatchingEngine(cfg, params, config=32)


# -- submit shims ------------------------------------------------------

def _engine():
    cfg, params = _setup()
    return ContinuousBatchingEngine(cfg, params,
                                    config=EngineConfig(max_len=32,
                                                        n_slots=2))


def test_submit_legacy_matches_params(rng):
    cfg, _ = _setup()
    p = rng.integers(0, cfg.vocab, 6).astype(np.int32)
    eng = _engine()
    r0 = eng.submit(p, SamplingParams(max_tokens=4, seed=7))
    with pytest.warns(DeprecationWarning, match="SamplingParams"):
        r1 = eng.submit(p, 4, seed=7)
    out = eng.drain()
    np.testing.assert_array_equal(out[r0], out[r1])


def test_submit_params_plus_legacy_kwargs_conflict(rng):
    cfg, _ = _setup()
    p = rng.integers(0, cfg.vocab, 6).astype(np.int32)
    with pytest.raises(TypeError, match="cannot be combined"):
        _engine().submit(p, SamplingParams(max_tokens=4), seed=1)


def test_submit_requires_budget(rng):
    cfg, _ = _setup()
    p = rng.integers(0, cfg.vocab, 6).astype(np.int32)
    with pytest.raises(TypeError, match="SamplingParams"):
        _engine().submit(p)


def test_submit_rejects_wrong_params_type(rng):
    cfg, _ = _setup()
    p = rng.integers(0, cfg.vocab, 6).astype(np.int32)
    with pytest.raises(TypeError, match="SamplingParams"):
        _engine().submit(p, "four")
