"""Unit tests for the serve metrics registry (`repro.serve.metrics`) and
the scheduler's incrementally-maintained gauges.

No model / no jax here: the registry is pure host-side bookkeeping and
must stay importable and testable on its own.
"""
import json
import math

import numpy as np
import pytest

from repro.serve.metrics import (Histogram, MetricsRegistry, _NULL_TIMER,
                                 format_report, log_buckets)
from repro.serve.scheduler import RequestScheduler


# ---------------------------------------------------------------------------
# Instruments
# ---------------------------------------------------------------------------


def test_counter_and_gauge():
    reg = MetricsRegistry()
    reg.counter("reqs").inc()
    reg.counter("reqs").inc(4)
    reg.gauge("depth").set(7)
    reg.gauge("depth").inc(-2)
    snap = reg.snapshot()
    assert snap["counters"]["reqs"] == 5
    assert snap["gauges"]["depth"] == 5.0


def test_log_buckets_cover_domain_monotonically():
    edges = log_buckets()
    assert np.all(np.diff(edges) > 0)
    assert edges[0] <= 1e-6 * (1 + 1e-9) and edges[-1] >= 1000.0


def test_histogram_exact_percentiles_within_ring(rng):
    h = Histogram()
    vals = rng.uniform(1e-4, 1.0, 500)
    for v in vals:
        h.observe(v)
    # ring holds everything -> percentiles are exact, not interpolated
    assert h.percentile(50) == pytest.approx(np.percentile(vals, 50))
    assert h.percentile(95) == pytest.approx(np.percentile(vals, 95))
    s = h.summary()
    assert s["count"] == 500
    assert s["min"] == pytest.approx(vals.min())
    assert s["max"] == pytest.approx(vals.max())
    assert s["mean"] == pytest.approx(vals.mean())


def test_histogram_bucket_fallback_bounded_error(rng):
    h = Histogram()
    vals = np.exp(rng.uniform(math.log(1e-5), math.log(10.0), 6000))
    for v in vals:
        h.observe(v)
    assert h.count > h._ring.maxlen  # raw ring overflowed
    # log-spaced edges bound the interpolation error by the bucket ratio
    ratio = 10 ** (1 / 4)
    for q in (50, 95):
        exact = np.percentile(vals, q)
        est = h.percentile(q)
        assert exact / ratio <= est <= exact * ratio


def test_timer_observes_elapsed_seconds():
    reg = MetricsRegistry()
    with reg.timer("phase"):
        pass
    h = reg.histogram("phase")
    assert h.count == 1
    assert 0 <= h.vmax < 1.0


def test_disabled_registry_is_inert_and_allocation_free():
    reg = MetricsRegistry(enabled=False)
    # the timer is a shared singleton no-op context, not a fresh object
    assert reg.timer("x") is _NULL_TIMER
    assert reg.timer("y") is reg.timer("z")
    with reg.timer("x"):
        pass
    reg.counter("c").inc(10)
    reg.gauge("g").set(3)
    reg.histogram("h").observe(1.0)
    reg.observe("h", 2.0)
    snap = reg.snapshot()
    assert snap == {"counters": {}, "gauges": {}, "histograms": {}}


def test_reset_zeroes_in_place_keeping_references():
    reg = MetricsRegistry()
    c = reg.counter("c")
    h = reg.histogram("h")
    c.inc(3)
    h.observe(0.5)
    reg.reset()
    assert c.value == 0 and h.count == 0 and h.total == 0.0
    # held references stay live after reset
    c.inc()
    h.observe(0.25)
    assert reg.snapshot()["counters"]["c"] == 1
    assert reg.snapshot()["histograms"]["h"]["count"] == 1
    assert reg.counter("c") is c and reg.histogram("h") is h


def test_snapshot_is_json_ready():
    reg = MetricsRegistry()
    reg.counter("n").inc()
    reg.gauge("q").set(2)
    for v in (1e-5, 3e-3, 0.2):
        reg.observe("t", v)
    s = json.dumps(reg.snapshot())
    back = json.loads(s)
    assert back["histograms"]["t"]["count"] == 3
    assert all(c >= 1 for _, c in back["histograms"]["t"]["buckets"])
    # and the human-readable report renders every non-empty instrument
    rep = format_report(reg.snapshot())
    assert "t:" in rep and "n=3" in rep and "p95=" in rep


# ---------------------------------------------------------------------------
# Scheduler gauges (incremental vs recount)
# ---------------------------------------------------------------------------


def _submit(sched, n_tokens=2):
    return sched.submit(np.arange(4, dtype=np.int32), n_tokens, 0.0,
                        key=None)


def test_scheduler_gauges_track_lifecycle():
    sched = RequestScheduler(2)
    for _ in range(3):
        _submit(sched)
    assert sched.gauges()["queue_depth"] == 3
    assert sched.gauges() | sched.recount() == sched.gauges()

    admitted = sched.admit()
    assert len(admitted) == 2
    g = sched.gauges()
    assert (g["queue_depth"], g["active_slots"], g["prefilling_slots"],
            g["decoding_slots"], g["free_slots"]) == (1, 2, 2, 0, 0)

    slot0, _ = admitted[0]
    sched.record_prefill(slot0, 11)  # PREFILLING -> DECODING
    g = sched.gauges()
    assert (g["prefilling_slots"], g["decoding_slots"]) == (1, 1)
    for k, v in sched.recount().items():
        assert g[k] == v, k

    # finish slot0: n_tokens=2 -> one decode token left
    toks = np.full(2, 5, np.int32)
    sched.decode_batch(dummy_key=None)
    sched.record_decode(toks)
    g = sched.gauges()
    assert g["finished"] == 1 and g["active_slots"] == 1
    for k, v in sched.recount().items():
        assert g[k] == v, k


def test_scheduler_unadmit_rolls_gauges_back_exactly():
    """The pool-starvation path: admit then unadmit must leave every
    incremental gauge exactly where a recount puts it — repeatedly, so
    drift (the bug class this pins) would accumulate and show."""
    sched = RequestScheduler(2)
    for _ in range(2):
        _submit(sched)
    for _ in range(5):  # repeated starved admission rounds
        admitted = sched.admit()
        assert admitted
        for slot, _ in reversed(admitted):
            sched.unadmit(slot)
        g = sched.gauges()
        for k, v in sched.recount().items():
            assert g[k] == v, f"gauge {k} drifted: {g[k]} != {v}"
    assert sched.gauges()["unadmitted"] == 10
    assert sched.gauges()["queue_depth"] == 2
    # requeue preserved FIFO order
    assert [r.rid for r in sched.queue] == [0, 1]
