"""Multi-device tests (subprocess: XLA device-count flag must precede jax
import, and the main test process must keep seeing 1 device)."""
import os
import subprocess
import sys


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8, timeout: int = 560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", code], env=env, timeout=timeout,
                       capture_output=True, text=True)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


def test_sharded_training_loss_decreases():
    out = _run("""
import warnings; warnings.filterwarnings('ignore')
import jax
import repro.configs as C
from repro.train.loop import Trainer
mesh = jax.make_mesh((2, 4), ('data', 'model'))
tr = Trainer(C.get_smoke('qwen2-moe-a2.7b'), seq_len=32, global_batch=8,
             total_steps=6, warmup=2, peak_lr=5e-3, mesh=mesh)
o = tr.run(6)
print('LOSSES', o['first_loss'], o['last_loss'])
assert o['last_loss'] == o['last_loss']  # not NaN
p = o['state'].params['blocks']['sub0_moe']['attn']['wq']['w']
print('SPEC', p.sharding.spec)
assert 'model' in str(p.sharding.spec)
""")
    assert "SPEC" in out


def test_elastic_remesh_restore():
    out = _run("""
import warnings, tempfile, os; warnings.filterwarnings('ignore')
import jax
import repro.configs as C
from repro.train.loop import Trainer
cfg = C.get_smoke('phi3-mini-3.8b')
with tempfile.TemporaryDirectory() as d:
    m1 = jax.make_mesh((2, 4), ('data', 'model'))
    Trainer(cfg, seq_len=32, global_batch=8, total_steps=4, ckpt_every=2,
            warmup=2, mesh=m1, workdir=d).run(4)
    m2 = jax.make_mesh((4, 2), ('data', 'model'))
    o = Trainer(cfg, seq_len=32, global_batch=8, total_steps=6, ckpt_every=2,
                warmup=2, mesh=m2, workdir=d).run(6)
    assert len(o['losses']) == 2  # resumed from step 4
    print('ELASTIC_OK')
""")
    assert "ELASTIC_OK" in out


def test_fsdp_zero3_training():
    # ZeRO-3 path: params sharded over data+model, bf16 gather pinning
    out = _run("""
import warnings, dataclasses; warnings.filterwarnings('ignore')
import jax
import repro.configs as C
from repro.train.loop import Trainer
cfg = C.get_smoke('deepseek-7b')
cfg = cfg.replace(parallel=dataclasses.replace(cfg.parallel,
                                               fsdp_params=True,
                                               grad_accum=2))
mesh = jax.make_mesh((2, 4), ('data', 'model'))
tr = Trainer(cfg, seq_len=32, global_batch=8, total_steps=4, warmup=2,
             peak_lr=5e-3, mesh=mesh)
o = tr.run(4)
p = o['state'].params['blocks']['sub0_attn']['mlp']['wi']['w']
spec = str(p.sharding.spec)
print('FSDP_SPEC', spec)
assert 'data' in spec and 'model' in spec  # 2-D sharded master weights
assert o['losses'][-1] == o['losses'][-1]
""")
    assert "FSDP_SPEC" in out


def test_compressed_allreduce_matches_mean():
    out = _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as PS
from jax.experimental.shard_map import shard_map
from repro.optim.compress import compressed_allreduce
mesh = jax.make_mesh((8,), ('pod',))
x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (8, 64)).astype(np.float32))
f = shard_map(lambda s: compressed_allreduce(s, 'pod'), mesh=mesh,
              in_specs=PS('pod'), out_specs=PS('pod'))
y = f(x)
want = jnp.broadcast_to(x.mean(0, keepdims=True), x.shape)
rel = float(jnp.abs(y - want).max() / jnp.abs(want).max())
print('REL', rel)
assert rel < 0.02
""")
    assert "REL" in out


def test_dryrun_entry_single_cell():
    # the dry-run module itself (512 fake devices) on the cheapest cell
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "smollm-135m",
         "--shape", "decode_32k", "--mesh", "single", "--out",
         "/tmp/dryrun_test", "--force"],
        env=env, timeout=560, capture_output=True, text=True, cwd=REPO)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "ok lower=" in r.stdout
