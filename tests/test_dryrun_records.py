"""Validate the recorded dry-run sweep (results/dryrun/): schema, coverage,
and memory-fit invariants. Skipped when no sweep has been run locally."""
import glob
import json
import os

import pytest

import repro.configs as C
from repro.configs.base import SHAPES, shape_applicable

OUT = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "results", "dryrun")

pytestmark = pytest.mark.skipif(
    not glob.glob(os.path.join(OUT, "*.json")),
    reason="no dry-run sweep recorded (run repro.launch.dryrun --all)")


def _records():
    out = {}
    for p in glob.glob(os.path.join(OUT, "*.json")):
        r = json.load(open(p))
        out[os.path.basename(p)[:-5]] = r
    return out


def test_full_cell_coverage():
    recs = _records()
    missing = []
    for arch in C.ARCH_IDS:
        for shape in SHAPES:
            for mesh in ("single", "multi"):
                name = f"{arch}__{shape}__{mesh}__qat"
                if name not in recs:
                    missing.append(name)
    assert not missing, missing


def test_skips_match_assignment():
    recs = _records()
    for arch in C.ARCH_IDS:
        cfg = C.get_config(arch)
        for shape_name, shape in SHAPES.items():
            ok, _ = shape_applicable(cfg, shape)
            rec = recs[f"{arch}__{shape_name}__single__qat"]
            assert ok == ("skipped" not in rec), (arch, shape_name)


def test_record_schema_and_roofline_terms():
    for name, r in _records().items():
        if r.get("skipped"):
            continue
        for key in ("memory", "cost", "roofline", "collectives",
                    "useful_flops_fraction", "n_params"):
            assert key in r, (name, key)
        t = r["roofline"]
        assert t["bottleneck"] in ("compute", "memory", "collective")
        assert t["roofline_bound_s"] >= max(
            t["compute_s"], t["memory_s"], t["collective_s"]) - 1e-9
        assert r["cost"]["flops"] > 0
        assert r["chips"] == (512 if r.get("mesh_kind") == "multi" else 256)


def test_decode_cells_fit_hbm():
    # v5e = 16 GB; decode/serve argument residency must fit per device
    for name, r in _records().items():
        if r.get("skipped") or r["kind"] != "decode":
            continue
        args_gib = r["memory"]["argument_bytes"] / 2 ** 30
        assert args_gib < 15.0, (name, args_gib)


def test_packed_serving_smaller_than_dense_reference():
    # where a quant-off reference exists, packed args must be smaller
    ref_dir = OUT.replace("dryrun", "dryrun_noswis")
    for p in glob.glob(os.path.join(ref_dir, "*decode*__off.json")):
        ref = json.load(open(p))
        name = os.path.basename(p)[:-5].replace("__off", "__qat")
        packed_path = os.path.join(OUT, name)
        if not os.path.exists(packed_path):
            continue
        packed = json.load(open(packed_path))
        assert (packed["memory"]["argument_bytes"]
                < ref["memory"]["argument_bytes"]), name
