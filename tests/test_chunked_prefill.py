"""Chunked prefill: token-exactness vs unchunked prefill (the chunk
schedule must be invisible in the output), chunk geometry edge cases
(chunk not dividing the prompt, chunk boundaries crossing prefix-cache
hits), and the liveness property the feature exists for — decode slots
keep producing tokens while a long prompt is mid-prefill."""
import functools

import jax
import numpy as np
import pytest

import repro.configs as C
from repro.models import params as pp
from repro.models.model import Model
from repro.serve import (ContinuousBatchingEngine, EngineConfig,
                         SamplingParams)
from repro.serve.scheduler import DECODING, PREFILLING

MAX_LEN = 96
CHUNK = 16


@functools.lru_cache(maxsize=1)
def _setup():
    cfg = C.get_smoke("smollm-135m").replace(compute_dtype="float32")
    params = pp.init_params(Model(cfg).build(), jax.random.key(0))
    return cfg, params


def _prompt(rng, s0):
    cfg, _ = _setup()
    return rng.integers(0, cfg.vocab, (s0,)).astype(np.int32)


def _engine(prefill_chunk=None, **kw):
    cfg, params = _setup()
    kw.setdefault("max_len", MAX_LEN)
    kw.setdefault("n_slots", 3)
    return ContinuousBatchingEngine(
        cfg, params, config=EngineConfig(prefill_chunk=prefill_chunk, **kw))


@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_chunked_matches_unchunked(rng, temperature):
    """Prompt lengths exercise every chunk geometry: shorter than one
    chunk, a whole number of chunks, and chunk-not-dividing-prompt (70 =
    4*16 + 6, 33 = 2*16 + 1)."""
    lens = (70, 33, 16, 5)
    prompts = [_prompt(rng, s0) for s0 in lens]

    def run(chunk):
        eng = _engine(chunk)
        rids = [eng.submit(p, SamplingParams(max_tokens=8,
                                             temperature=temperature, seed=i))
                for i, p in enumerate(prompts)]
        out = eng.drain()
        return [out[r] for r in rids]

    for got, want in zip(run(CHUNK), run(None)):
        np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("temperature", [0.0, 0.7])
def test_chunked_staggered_matches_unchunked_lockstep(rng, temperature):
    """A request joining while a long prompt is mid-chunk-prefill must not
    change anyone's tokens vs an unchunked lockstep run."""
    pa = _prompt(rng, 61)  # 3 full chunks + 13
    pb = _prompt(rng, 9)

    def run(chunk, stagger):
        eng = _engine(chunk, n_slots=2)
        out = {}
        ra = eng.submit(pa, SamplingParams(max_tokens=8,
                                           temperature=temperature, seed=1))
        rb = None
        if not stagger:
            rb = eng.submit(pb, SamplingParams(max_tokens=6,
                                               temperature=temperature,
                    seed=2))
        for _ in range(2):  # A is mid-prefill (chunked) or decoding
            for f in eng.step():
                out[f.rid] = f.tokens
        if stagger:
            rb = eng.submit(pb, SamplingParams(max_tokens=6,
                                               temperature=temperature,
                    seed=2))
        for rid, full in eng.drain().items():
            s0 = len(pa) if rid == ra else len(pb)
            out[rid] = full[s0:]
        return out[ra], out[rb]

    a_ref, b_ref = run(None, stagger=False)
    for stagger in (False, True):
        a, b = run(CHUNK, stagger=stagger)
        np.testing.assert_array_equal(a, a_ref)
        np.testing.assert_array_equal(b, b_ref)


def test_chunk_boundaries_cross_prefix_cache_hits(rng):
    """Second request shares a 40-token prefix (not chunk-aligned: 40 =
    2*16 + 8): its suffix chunks start mid-stream at the cached-block
    boundary and must still reproduce the no-cache tokens exactly."""
    shared = _prompt(rng, 40)
    tails = [_prompt(rng, 11), _prompt(rng, 3)]
    prompts = [np.concatenate([shared, t]) for t in tails]

    eng = _engine(CHUNK, n_slots=2)
    outs = []
    for i, p in enumerate(prompts):
        rid = eng.submit(p, SamplingParams(max_tokens=6, seed=i))
        outs.append(eng.drain()[rid])  # drain so the first commits blocks
    stats = eng.prefix_stats()
    assert stats["hit_rate"] > 0
    assert stats["saved_tokens"] > 0

    ref = _engine(None, n_slots=2, prefix_cache=False)
    for i, (p, got) in enumerate(zip(prompts, outs)):
        rid = ref.submit(p, SamplingParams(max_tokens=6, seed=i))
        np.testing.assert_array_equal(got, ref.drain()[rid])


def test_decode_continues_while_long_prompt_prefills(rng):
    """The point of chunked prefill: a decoding slot keeps producing one
    token per step on every step the long prompt spends in PREFILLING."""
    eng = _engine(CHUNK, n_slots=2)
    rs = eng.submit(_prompt(rng, 6), SamplingParams(max_tokens=40, seed=3))
    eng.step()
    slot_short = next(s for s, st in enumerate(eng.scheduler.slots)
                      if st is not None and st.req.rid == rs)
    rl = eng.submit(_prompt(rng, 80), SamplingParams(max_tokens=4,
                                                     seed=4))  # 5 chunks of 16

    phases, gens = [], []
    for _ in range(8):
        eng.step()
        long_states = [st for st in eng.scheduler.slots
                       if st is not None and st.req.rid == rl]
        phases.append(long_states[0].phase if long_states else "gone")
        gens.append(eng.scheduler.slots[slot_short].n_gen)
    prefill_steps = [i for i, ph in enumerate(phases) if ph == PREFILLING]
    assert len(prefill_steps) >= 3  # the long prompt spent steps chunking
    assert DECODING in phases  # and eventually flipped to decode
    for i in prefill_steps:
        # the short slot gained a token on every one of those steps
        if i == 0:
            assert gens[0] >= 2
        else:
            assert gens[i] == gens[i - 1] + 1


def test_prefilling_slots_invisible_to_decode(rng):
    """While chunks land, the slot is PREFILLING, produced no tokens, and
    its block table still points at the trash block (decode dummy rows
    must not write into live blocks)."""
    eng = _engine(CHUNK, n_slots=2)
    rid = eng.submit(_prompt(rng, 80), SamplingParams(max_tokens=4, seed=0))
    eng.step()
    (slot, st), = [(s, st) for s, st in enumerate(eng.scheduler.slots)
                   if st is not None]
    assert st.req.rid == rid and st.phase == PREFILLING
    assert st.n_gen == 0 and not st.tokens
    assert not eng.scheduler.needs_decode()
    assert np.all(eng.cache.block_tables[slot] == 0)
    eng.drain()


def test_chunk_requires_block_mode(rng):
    cfg, params = _setup()
    with pytest.raises(ValueError, match="prefill_chunk"):
        ContinuousBatchingEngine(cfg, params,
                                 config=EngineConfig(max_len=MAX_LEN,
                                                     n_slots=2,
                prefix_cache=False, prefill_chunk=CHUNK))


def test_chunk_rounds_up_to_block_multiple(rng):
    eng = _engine(prefill_chunk=9, block_size=8)
    assert eng.prefill_chunk == 16
    rid = eng.submit(_prompt(rng, 40), SamplingParams(max_tokens=4, seed=0))
    out = eng.drain()
    assert out[rid].shape == (44,)


def test_reset_reuses_engine(rng):
    """reset() returns an idle engine to a fresh state: same submissions
    reproduce the same tokens, and prefix stats start from zero."""
    eng = _engine(CHUNK, n_slots=2)
    p = _prompt(rng, 40)
    r0 = eng.submit(p, SamplingParams(max_tokens=6, seed=0))
    first = eng.drain()[r0]
    assert eng.prefix_stats()["prefill_tokens"] > 0
    eng.reset()
    assert eng.prefix_stats()["prefill_tokens"] == 0
    r1 = eng.submit(p, SamplingParams(max_tokens=6, seed=0))
    np.testing.assert_array_equal(eng.drain()[r1], first)
