"""Paper Eqs. 8-10 / Fig. 2: lossless-quantization probabilities."""
import math

import numpy as np

from repro.core import probability as P
from repro.core import selection


def test_orderings_and_limits():
    t = P.lossless_table()
    for a, b, c in zip(t["swis"], t["swis_c"], t["layerwise"]):
        assert a >= b - 1e-12 >= c - 2e-12
    assert abs(t["swis"][8] - 1) < 1e-12
    assert abs(t["swis_c"][8] - 1) < 1e-12
    assert abs(t["layerwise"][8] - 1) < 1e-12
    assert abs(t["swis"][0] - 2 ** -8) < 1e-12


def test_fig2_reference_values():
    # spot values computable by hand from Eq. 8
    assert abs(P.p_lossless_swis(4) - sum(
        math.comb(8, n) for n in range(5)) / 256) < 1e-12
    # SWIS-C N=1: representable = {0} + 8 single bits = 9 values
    assert abs(P.p_lossless_swis_c(1) - 9 / 256) < 1e-12
    # layer-wise N=2: 4 representable values on fixed support
    assert abs(P.p_lossless_layerwise(2) - 4 / 256) < 1e-12


def test_monte_carlo_agreement(rng):
    vals = rng.integers(0, 256, 100000)
    for variant, closed in (("swis", P.p_lossless_swis),
                            ("swis_c", P.p_lossless_swis_c)):
        for n in (2, 3, 4):
            cand = selection.combo_candidates(n, 8, variant)
            ok = np.zeros(len(vals), bool)
            for c in range(cand.shape[0]):
                ok |= np.isin(vals, cand[c].astype(np.int64))
            assert abs(ok.mean() - closed(n)) < 0.01, (variant, n)
