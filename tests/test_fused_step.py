"""Fused mixed chunk+decode step: the per-step prefill chunk and the
decode batch share ONE model dispatch, and that fusion must be invisible
in the output — every request's tokens match the separate
chunk-then-decode path exactly, across staggered arrivals, prefix-cache
hits, seeded temperature sampling, and both attention backends (gather
and paged). The dispatch-count tests pin the property the feature exists
for: a chunk-servicing step in fused mode records exactly one model
dispatch (vs two on the separate path) while decode tokens still land in
the same step."""
import functools

import jax
import numpy as np
import pytest

import repro.configs as C
import repro.serve.trace as tr
from conftest import requires_hypothesis
from repro.models import params as pp
from repro.models.model import Model
from repro.serve import (ContinuousBatchingEngine, EngineConfig,
                         SamplingParams)

MAX_LEN = 128
CHUNK = 16


@functools.lru_cache(maxsize=1)
def _setup():
    cfg = C.get_smoke("smollm-135m").replace(compute_dtype="float32")
    params = pp.init_params(Model(cfg).build(), jax.random.key(0))
    return cfg, params


def _prompt(rng, s0):
    cfg, _ = _setup()
    return rng.integers(0, cfg.vocab, (s0,)).astype(np.int32)


def _engine(fused, **kw):
    cfg, params = _setup()
    kw.setdefault("max_len", MAX_LEN)
    kw.setdefault("n_slots", 3)
    kw.setdefault("prefill_chunk", CHUNK)
    return ContinuousBatchingEngine(
        cfg, params, config=EngineConfig(fused_step=fused, **kw))


def _serve(eng, prompts, temps, stagger_after):
    """Submit ``prompts`` (staggering the tail after a couple of steps)
    and return the full prompt+generated array per submission index."""
    out = {}
    cut = stagger_after
    rids = [eng.submit(p, SamplingParams(max_tokens=6,
                                         temperature=temps[i], seed=i))
            for i, p in enumerate(prompts[:cut])]
    for _ in range(2):
        for f in eng.step():
            out[f.rid] = np.concatenate([f.prompt, f.tokens])
    rids += [eng.submit(p, SamplingParams(max_tokens=6,
                                          temperature=temps[cut + i],
                                          seed=cut + i))
             for i, p in enumerate(prompts[cut:])]
    out.update(eng.drain())
    return [out[rid] for rid in rids]


@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_fused_matches_separate_staggered(rng, temperature):
    """Staggered arrivals + chunked prefill + seeded sampling: every
    request's full token stream is identical fused vs separate. Prompt
    lengths cover every chunk geometry (sub-chunk, exact multiple,
    chunk-not-dividing: 61 = 3*16 + 13)."""
    lens = (61, 9, 33, 16, 5)
    prompts = [_prompt(rng, s0) for s0 in lens]
    temps = [temperature] * len(prompts)

    def run(fused):
        return _serve(_engine(fused), prompts, temps, stagger_after=3)

    for got, want in zip(run(True), run(False)):
        np.testing.assert_array_equal(got, want)


def test_fused_matches_separate_with_prefix_hits(rng):
    """Requests sharing a non-chunk-aligned 40-token prefix (40 = 2*16 +
    8): the second request's suffix-only fused chunks start mid-stream at
    the cached-block boundary and must reproduce the separate-path tokens
    exactly — with the prefix cache actually hitting in both runs."""
    shared = _prompt(rng, 40)
    tails = [_prompt(rng, 13), _prompt(rng, 3)]
    prompts = [np.concatenate([shared, t]) for t in tails]

    def run(fused):
        eng = _engine(fused, n_slots=2)
        outs = []
        for i, p in enumerate(prompts):
            rid = eng.submit(p, SamplingParams(max_tokens=6, seed=i))
            outs.append(eng.drain()[rid])  # drain so blocks commit
        assert eng.prefix_stats()["hit_rate"] > 0
        assert eng.prefix_stats()["saved_tokens"] > 0
        return outs

    for got, want in zip(run(True), run(False)):
        np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("paged_impl", ["xla", "pallas_interpret"])
def test_fused_matches_separate_paged(rng, paged_impl):
    """The fused mixed batch routes per-row query counts through the
    paged kernel (scalar-prefetched q_lens): tokens must match the
    separate path under the same paged impl."""
    prompts = [_prompt(rng, 37), _prompt(rng, 6)]
    n_tok = 3 if paged_impl == "pallas_interpret" else 6

    def run(fused):
        eng = _engine(fused, n_slots=2, use_paged_kernel=True,
                      paged_impl=paged_impl)
        rids = [eng.submit(p, SamplingParams(max_tokens=n_tok, seed=i))
                for i, p in enumerate(prompts)]
        out = eng.drain()
        return [out[r] for r in rids]

    for got, want in zip(run(True), run(False)):
        np.testing.assert_array_equal(got, want)


def test_fused_chunk_step_is_one_dispatch(rng):
    """The acceptance criterion: while a chunk group is in flight, every
    fused engine step issues exactly ONE model dispatch, and decode
    tokens still arrive in that same step (PREFILL_CHUNK and DECODE_STEP
    trace events between the same step boundaries)."""
    eng = _engine(True, n_slots=2)
    eng.submit(_prompt(rng, 6), SamplingParams(max_tokens=40, seed=0))
    eng.step()  # short request is now DECODING
    eng.submit(_prompt(rng, 80), SamplingParams(max_tokens=4, seed=1))

    c = eng.metrics_registry.counter("step.model_dispatches")
    fused_chunk_steps = 0
    for _ in range(8):
        n_ev = len(eng.tracer)
        before = c.value
        eng.step()
        kinds = {e.kind for e in eng.tracer.events()[n_ev:]}
        if tr.PREFILL_CHUNK in kinds:
            fused_chunk_steps += 1
            assert c.value - before == 1
            assert tr.DECODE_STEP in kinds
        if not eng._prefill_groups:
            break
    assert fused_chunk_steps >= 3  # 80 tokens / 16-chunk = 5 chunks
    snap = eng.metrics_registry.snapshot()
    mixed = snap["histograms"]["step.mixed_dispatch_s"]
    # +1: the setup step serviced the short prompt's single chunk
    # through the same mixed launch
    assert mixed["count"] == fused_chunk_steps + 1
    eng.drain()


def test_separate_chunk_step_is_two_dispatches(rng):
    """Control for the dispatch-count assertion: the separate path pays
    one dispatch for the chunk and one for the decode batch on the same
    step."""
    eng = _engine(False, n_slots=2)
    eng.submit(_prompt(rng, 6), SamplingParams(max_tokens=40, seed=0))
    eng.step()
    eng.submit(_prompt(rng, 80), SamplingParams(max_tokens=4, seed=1))

    c = eng.metrics_registry.counter("step.model_dispatches")
    checked = 0
    for _ in range(8):
        n_ev = len(eng.tracer)
        before = c.value
        eng.step()
        kinds = {e.kind for e in eng.tracer.events()[n_ev:]}
        if tr.PREFILL_CHUNK in kinds and tr.DECODE_STEP in kinds:
            checked += 1
            assert c.value - before == 2
        if not eng._prefill_groups:
            break
    assert checked >= 3
    assert "step.mixed_dispatch_s" not in \
        eng.metrics_registry.snapshot()["histograms"]
    eng.drain()


# -- geometry sweep: fused == separate across (chunk, block, prompt) ----

SWEEP = [(8, 4, 21), (8, 8, 30), (16, 8, 33), (16, 4, 13)]


def _parity_one(prefill_chunk, block_size, prompt_len):
    rng = np.random.default_rng(prompt_len * 31 + block_size)
    prompts = [_prompt(rng, prompt_len), _prompt(rng, 5)]

    def run(fused):
        eng = _engine(fused, n_slots=2, prefill_chunk=prefill_chunk,
                      block_size=block_size)
        rids = [eng.submit(p, SamplingParams(max_tokens=4,
                                             temperature=0.6, seed=i))
                for i, p in enumerate(prompts)]
        out = eng.drain()
        return [out[r] for r in rids]

    for got, want in zip(run(True), run(False)):
        np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("prefill_chunk,block_size,prompt_len", SWEEP)
def test_fused_geometry_sweep(prefill_chunk, block_size, prompt_len):
    """Deterministic fallback for the hypothesis sweep below — runs
    everywhere, covers chunk/block/prompt geometries including
    chunk == block and prompt shorter than one chunk."""
    _parity_one(prefill_chunk, block_size, prompt_len)


@pytest.mark.slow
@requires_hypothesis()
def test_fused_geometry_sweep_hypothesis():
    """Property form of the sweep when hypothesis is installed: any
    (prefill_chunk, block_size, prompt_len) with chunk a block multiple
    must be fused/separate token-exact."""
    import hypothesis as hyp
    from hypothesis import strategies as st

    @hyp.settings(max_examples=5, deadline=None,
                  suppress_health_check=list(hyp.HealthCheck))
    @hyp.given(bs=st.sampled_from([4, 8]),
               mult=st.integers(min_value=1, max_value=3),
               prompt_len=st.integers(min_value=2, max_value=48))
    def prop(bs, mult, prompt_len):
        _parity_one(bs * mult, bs, prompt_len)

    prop()
