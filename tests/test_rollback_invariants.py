"""Rollback invariants for the block arena under speculative decode.

Extends the PR-4 unadmit leak test (``test_prefix_cache.py::
test_unadmit_under_pool_pressure_leaks_no_refcounts``) into a rule-based
state machine: after ANY sequence of admissions (with prefix hits),
speculative accept/reject rounds, unadmits, finishes, and evictions, the
:class:`BlockPool` + :class:`RadixPrefixCache` pair must satisfy

  * free ∪ slot-referenced ∪ committed == all non-reserved blocks (no
    leaked block is ever stranded outside all three sets);
  * the trash block 0 keeps refcount 1, never enters the free list and
    is never committed;
  * no slot table references a freed block, and every block's refcount
    equals the number of slot tables holding it.

The harness mirrors the engine's block accounting contract
(kv_cache.py "Speculative commit/rollback contract"): rejected drafts
need no block-level rollback — a spec round only ever *feeds* accepted
tokens, allocates lazily at block boundaries, and finish commits only
the full blocks of fed tokens. The hypothesis machine is the slow-tier
sweep; the seeded random walk is its deterministic tier-1 fallback, and
an engine-level test pins the same quiescence on the real serve stack
after speculative serving with truncated (rejection-heavy) drafts.
"""
import collections
import functools

import jax
import numpy as np
import pytest

import repro.configs as C
from conftest import requires_hypothesis
from repro.models import params as pp
from repro.models.model import Model
from repro.serve import (BlockPool, ContinuousBatchingEngine, EngineConfig,
                         RadixPrefixCache, SamplingParams)

BS = 4
N_BLOCKS = 16


class _Arena:
    """Host-side mirror of the engine's per-slot block accounting: the
    same BlockPool/RadixPrefixCache calls the engine makes, minus the
    device arena (the K/V payload is irrelevant to the invariants)."""

    def __init__(self, n_blocks=N_BLOCKS, bs=BS):
        self.bs = bs
        self.pool = BlockPool(n_blocks, bs)
        self.trie = RadixPrefixCache(self.pool)
        self.slots = {}  # slot -> {"blocks": [ids], "seq": fed tokens}
        self._next_slot = 0

    def _blocks_for(self, n_tokens):
        return -(-n_tokens // self.bs)

    def _grow(self, state, n_new):
        """Lazily allocate blocks to cover ``n_new`` more fed tokens
        (evicting if needed). Returns the token count actually coverable
        — the engine's per-row budget clamp in miniature."""
        while n_new:
            need = (self._blocks_for(len(state["seq"]) + n_new)
                    - len(state["blocks"]))
            if need <= 0:
                return n_new
            if self.pool.n_free() < need:
                self.trie.evict(need - self.pool.n_free())
            ids = self.pool.alloc(need)
            if ids is not None:
                self.pool.incref(ids)
                state["blocks"] += ids
                return n_new
            n_new -= 1  # arena exhausted: feed fewer tokens this round
        return 0

    # -- engine-contract operations ------------------------------------

    def admit(self, prompt):
        """Prefix-match + incref the hit chain, allocate the uncovered
        prompt blocks; on pool starvation roll the speculative
        references back (scheduler.unadmit + prefix_cache.release)."""
        matched = self.trie.match(prompt)
        self.pool.incref(matched)
        own = self._blocks_for(len(prompt)) - len(matched)
        if self.pool.n_free() < own:
            self.trie.evict(own - self.pool.n_free())
        ids = self.pool.alloc(own)
        if ids is None:
            self.trie.release(matched)  # the unadmit rollback
            return None
        self.pool.incref(ids)
        slot = self._next_slot
        self._next_slot += 1
        self.slots[slot] = {"blocks": matched + ids,
                            "seq": np.asarray(prompt, np.int32)}
        return slot

    def spec_round(self, slot, rng, proposed, accepted):
        """One speculative round: ``accepted <= proposed`` drafts matched
        the verify targets, and the bonus token always lands — so
        ``accepted + 1`` tokens are fed. Rejected drafts touch no block
        state at all (their writes are overwritten before commit)."""
        state = self.slots[slot]
        emit = self._grow(state, min(accepted, proposed) + 1)
        toks = rng.integers(0, 512, (emit,)).astype(np.int32)
        state["seq"] = np.concatenate([state["seq"], toks])

    def unadmit(self, slot):
        """Failed admission rollback: every reference taken at admit is
        dropped; own (uncommitted) blocks go straight back to the free
        list."""
        state = self.slots.pop(slot)
        self.trie.release(state["blocks"])

    def finish(self, slot):
        """Commit the full blocks of the fed sequence minus the last
        token (the engine's ``seq = prompt + tokens[:-1]``), then release
        the slot's references."""
        state = self.slots.pop(slot)
        seq = state["seq"][:-1]
        self.trie.commit(seq, state["blocks"][:len(seq) // self.bs])
        self.trie.release(state["blocks"])

    # -- the invariants -------------------------------------------------

    def check(self):
        pool, trie = self.pool, self.trie
        free = set(pool._free)
        committed = set(trie._node_of_block)
        held = collections.Counter(
            b for s in self.slots.values() for b in s["blocks"])
        # trash block 0: refcount pinned, never free, never committed
        assert pool.refcount[0] == 1
        assert 0 not in free and 0 not in committed
        # no slot table references a freed block
        assert not set(held) & free
        assert not committed & free
        # coverage: free ∪ live == all non-reserved blocks (a block
        # outside all three sets is leaked forever)
        assert free | set(held) | committed == set(range(1, pool.n_blocks))
        # refcount == number of slot tables holding the block, exactly
        for b in range(1, pool.n_blocks):
            assert pool.refcount[b] == held.get(b, 0), (b, held.get(b, 0))


def _random_walk(seed, n_ops=120):
    rng = np.random.default_rng(seed)
    arena = _Arena()
    finished_seqs = []
    for _ in range(n_ops):
        op = rng.choice(["admit", "spec", "unadmit", "finish", "evict"],
                        p=[0.3, 0.3, 0.1, 0.2, 0.1])
        if op == "admit" and len(arena.slots) < 4:
            prompt = rng.integers(0, 512, (int(rng.integers(1, 10)),))
            if finished_seqs and rng.random() < 0.5:
                # replay a finished prefix so admissions hit the trie
                base = finished_seqs[rng.integers(len(finished_seqs))]
                prompt = np.concatenate([base[:rng.integers(1, len(base)
                                                            + 1)], prompt])
            arena.admit(prompt.astype(np.int32))
        elif op == "spec" and arena.slots:
            slot = list(arena.slots)[rng.integers(len(arena.slots))]
            proposed = int(rng.integers(1, 5))
            arena.spec_round(slot, rng, proposed,
                             int(rng.integers(0, proposed + 1)))
        elif op == "unadmit" and arena.slots:
            slot = list(arena.slots)[rng.integers(len(arena.slots))]
            arena.unadmit(slot)
        elif op == "finish" and arena.slots:
            slot = list(arena.slots)[rng.integers(len(arena.slots))]
            if len(arena.slots[slot]["seq"]) > 1:
                finished_seqs.append(arena.slots[slot]["seq"])
                arena.finish(slot)
        elif op == "evict":
            arena.trie.evict(int(rng.integers(1, 4)))
        arena.check()
    # quiesce: every in-flight slot finishes, nothing may stay stranded
    for slot in list(arena.slots):
        if len(arena.slots[slot]["seq"]) > 1:
            arena.finish(slot)
        else:
            arena.unadmit(slot)
    arena.check()
    assert (arena.pool.refcount[1:] == 0).all()


@pytest.mark.parametrize("seed", range(6))
def test_rollback_random_walk(seed):
    """Deterministic tier-1 fallback for the hypothesis machine below:
    seeded random interleavings of the same rule set, invariants checked
    after every operation and after full quiescence."""
    _random_walk(seed)


@pytest.mark.slow
@requires_hypothesis()
def test_rollback_state_machine():
    """Rule-based form: hypothesis drives arbitrary interleavings of
    admit / spec accept-reject / unadmit / finish / evict and shrinks
    any violating sequence to a minimal reproduction."""
    from hypothesis import settings
    from hypothesis import strategies as st
    from hypothesis.stateful import (RuleBasedStateMachine, invariant,
                                     precondition, rule,
                                     run_state_machine_as_test)

    class Machine(RuleBasedStateMachine):
        def __init__(self):
            super().__init__()
            self.arena = _Arena()
            self.rng = np.random.default_rng(0)
            self.finished_seqs = []

        @rule(n_toks=st.integers(1, 12), reuse=st.booleans())
        def admit(self, n_toks, reuse):
            prompt = self.rng.integers(0, 512, (n_toks,)).astype(np.int32)
            if reuse and self.finished_seqs:
                base = self.finished_seqs[
                    self.rng.integers(len(self.finished_seqs))]
                prompt = np.concatenate([base, prompt])[:3 * BS]
            self.arena.admit(prompt)

        @precondition(lambda self: self.arena.slots)
        @rule(proposed=st.integers(1, 4), data=st.data())
        def spec_round(self, proposed, data):
            slot = data.draw(st.sampled_from(sorted(self.arena.slots)))
            accepted = data.draw(st.integers(0, proposed))
            self.arena.spec_round(slot, self.rng, proposed, accepted)

        @precondition(lambda self: self.arena.slots)
        @rule(data=st.data())
        def unadmit(self, data):
            self.arena.unadmit(
                data.draw(st.sampled_from(sorted(self.arena.slots))))

        @precondition(lambda self: any(
            len(s["seq"]) > 1 for s in self.arena.slots.values()))
        @rule(data=st.data())
        def finish(self, data):
            slot = data.draw(st.sampled_from(sorted(
                s for s, v in self.arena.slots.items()
                if len(v["seq"]) > 1)))
            self.finished_seqs.append(self.arena.slots[slot]["seq"])
            self.arena.finish(slot)

        @rule(n=st.integers(1, 4))
        def evict(self, n):
            self.arena.trie.evict(n)

        @invariant()
        def invariants_hold(self):
            self.arena.check()

    run_state_machine_as_test(
        Machine, settings=settings(max_examples=25, stateful_step_count=40,
                                   deadline=None))


# -- the same quiescence on the real engine, speculating ----------------


MAX_LEN = 64


@functools.lru_cache(maxsize=1)
def _setup():
    cfg = C.get_smoke("smollm-135m").replace(compute_dtype="float32")
    params = pp.init_params(Model(cfg).build(), jax.random.key(0))
    return cfg, params


def test_spec_serving_leaves_pool_quiescent(rng):
    """After speculative serving with a truncated (rejection-heavy)
    draft, shared prefixes and eviction pressure: draining the engine
    leaves every non-reserved block either free or committed-unreferenced
    — the engine-level face of the state machine's invariants."""
    cfg, params = _setup()
    eng = ContinuousBatchingEngine(
        cfg, params, config=EngineConfig(
            max_len=MAX_LEN, n_slots=2, block_size=BS, n_cache_blocks=4,
            spec_decode=True, spec_k=3, packed=True, draft_slices=2))
    shared = rng.integers(0, cfg.vocab, (2 * BS,)).astype(np.int32)
    for i in range(5):
        tail = rng.integers(0, cfg.vocab,
                            (int(rng.integers(3, 12)),)).astype(np.int32)
        eng.submit(np.concatenate([shared, tail]) if i % 2 else tail,
                   SamplingParams(max_tokens=6, temperature=0.6, seed=i))
    eng.drain()
    counters = eng.metrics_registry.snapshot()["counters"]
    assert counters["spec.proposed"] > 0  # speculation actually ran
    pool = eng.prefix_cache.pool
    assert pool.refcount[0] == 1  # trash block stays pinned
    np.testing.assert_array_equal(pool.refcount[1:], 0)
    committed = {b for b in range(1, pool.n_blocks)
                 if eng.prefix_cache.is_committed(b)}
    free = set(pool._free)
    assert free.isdisjoint(committed)
    assert free | committed == set(range(1, pool.n_blocks))
