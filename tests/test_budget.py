"""Cross-layer shift-budget allocator (beyond-paper ablation, core/budget.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.core import budget
from repro.core.swis import QuantConfig
from repro.models import params as pp
from repro.models.model import Model


@pytest.fixture(scope="module")
def setup():
    cfg = C.get_smoke("phi3-mini-3.8b").replace(compute_dtype="float32")
    params = pp.init_params(Model(cfg).build(), jax.random.key(0))
    qcfg = QuantConfig(method="swis", n_shifts=2, group_size=4)
    prof = budget.sensitivity_profile(params, qcfg, levels=(1, 2, 3, 4))
    sizes = budget.leaf_sizes(params)
    return cfg, params, qcfg, prof, sizes


def test_profile_monotone(setup):
    _, _, _, prof, _ = setup
    assert len(prof) >= 5  # per-layer units from stacked leaves
    for costs in prof.values():
        vals = [costs[n] for n in sorted(costs)]
        assert all(a >= b - 1e-9 for a, b in zip(vals, vals[1:]))


@pytest.mark.parametrize("target", [1.5, 2.0, 3.0])
def test_allocation_hits_budget(setup, target):
    _, _, _, prof, sizes = setup
    alloc = budget.allocate(prof, sizes, target_avg=target,
                            levels=(1, 2, 3, 4))
    assert abs(alloc.effective_shifts - target) < 0.5
    assert all(n in (1, 2, 3, 4) for n in alloc.shifts.values())


def test_allocation_cost_beats_uniform_floor(setup):
    # at avg 2.5 the allocated MSE++ must be <= the uniform-2 cost (more
    # bits) and >= uniform-3 (fewer bits): sandwich sanity
    _, _, _, prof, sizes = setup
    alloc = budget.allocate(prof, sizes, target_avg=2.5, levels=(1, 2, 3, 4))
    c2 = sum(c[2] for c in prof.values())
    c3 = sum(c[3] for c in prof.values())
    assert c3 - 1e-9 <= alloc.total_cost <= c2 + 1e-9


def test_quantize_with_allocation_applies(setup):
    cfg, params, qcfg, prof, sizes = setup
    alloc = budget.allocate(prof, sizes, target_avg=2.0, levels=(1, 2, 3, 4))
    qp = budget.quantize_with_allocation(params, qcfg, alloc)
    # quantized leaves changed; non-eligible leaves untouched
    w0 = params["blocks"]["sub0_attn"]["mlp"]["wi"]["w"]
    w1 = qp["blocks"]["sub0_attn"]["mlp"]["wi"]["w"]
    assert float(jnp.abs(w0 - w1).max()) > 0
    np.testing.assert_array_equal(np.asarray(params["embed"]["tok"]),
                                  np.asarray(qp["embed"]["tok"]))
