"""Self-speculative multi-token decode: a truncated-bit-slice draft pass
proposes up to ``spec_k`` tokens per step and ONE full-precision verify
launch scores them all — and that speculation must be invisible in the
output. Every request's token stream matches plain (non-speculative)
decode exactly, for EVERY accept pattern: staggered arrivals, seeded
temperature sampling, prefix-cache hits, chunked-prefill overlap (fused
and separate), both paged-attention impls, and truncated drafts that
actually get rejected. The counter/trace tests pin the mechanism:
``draft_slices == total_slices`` means the draft IS the target model, so
the accept rate is exactly 1.0; a spec step issues ``k_max + 1`` model
dispatches (k_max drafts + one verify)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
import repro.serve.trace as tr
from conftest import requires_hypothesis
from repro.core import packing, swis
from repro.kernels import ops, ref
from repro.models import params as pp
from repro.models.model import Model
from repro.serve import (ContinuousBatchingEngine, EngineConfig,
                         SamplingParams)
from repro.serve.quantized import total_slices

MAX_LEN = 96


@functools.lru_cache(maxsize=1)
def _setup():
    cfg = C.get_smoke("smollm-135m").replace(compute_dtype="float32")
    params = pp.init_params(Model(cfg).build(), jax.random.key(0))
    return cfg, params


def _prompt(rng, s0):
    cfg, _ = _setup()
    return rng.integers(0, cfg.vocab, (s0,)).astype(np.int32)


def _engine(spec, **kw):
    cfg, params = _setup()
    kw.setdefault("max_len", MAX_LEN)
    kw.setdefault("n_slots", 3)
    if spec:
        kw.setdefault("spec_k", 3)
    return ContinuousBatchingEngine(
        cfg, params, config=EngineConfig(spec_decode=spec, **kw))


def _drain_ordered(eng, rids):
    out = eng.drain()
    return [out[r] for r in rids]


# -- token-exact parity vs plain decode ---------------------------------


@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_spec_matches_plain_staggered(rng, temperature):
    """Staggered arrivals + seeded sampling: every request's full token
    stream is identical with speculation on vs off. Mixed budgets force
    every per-row draft budget (k_rows) pattern: full spec_k, clamped
    tail (remaining-1 < spec_k), and the k_max == 0 plain-decode
    degeneration on the last token."""
    prompts = [_prompt(rng, s0) for s0 in (17, 5, 9, 12)]
    budgets = [8, 3, 1, 6]

    def run(spec):
        eng = _engine(spec)
        out = {}
        rids = [eng.submit(p, SamplingParams(max_tokens=budgets[i],
                                             temperature=temperature,
                                             seed=i))
                for i, p in enumerate(prompts[:2])]
        for _ in range(2):
            for f in eng.step():
                out[f.rid] = np.concatenate([f.prompt, f.tokens])
        rids += [eng.submit(p, SamplingParams(max_tokens=budgets[2 + i],
                                              temperature=temperature,
                                              seed=2 + i))
                 for i, p in enumerate(prompts[2:])]
        out.update(eng.drain())
        return [out[r] for r in rids]

    for got, want in zip(run(True), run(False)):
        np.testing.assert_array_equal(got, want)


def test_spec_matches_plain_with_prefix_hits(rng):
    """Requests sharing a 24-token prefix: the speculative run must hit
    the prefix cache (committed blocks are full-precision by the verify
    rewrite) and reproduce the plain-path tokens exactly."""
    shared = _prompt(rng, 24)
    prompts = [np.concatenate([shared, _prompt(rng, t)]) for t in (9, 4)]

    def run(spec):
        eng = _engine(spec, n_slots=2)
        outs = []
        for i, p in enumerate(prompts):
            rid = eng.submit(p, SamplingParams(max_tokens=6, seed=i))
            outs.append(eng.drain()[rid])  # drain so blocks commit
        assert eng.prefix_stats()["hit_rate"] > 0
        return outs

    for got, want in zip(run(True), run(False)):
        np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("fused", [False, True])
def test_spec_matches_plain_chunked_prefill_overlap(rng, fused):
    """A long prompt prefilling chunk-by-chunk while another slot decodes
    speculatively around it: chunk-servicing steps take the (fused or
    separate) prefill path and pure-decode steps speculate, with
    token-exact output either way."""
    short, long = _prompt(rng, 5), _prompt(rng, 50)

    def run(spec):
        eng = _engine(spec, n_slots=2, prefill_chunk=16, fused_step=fused)
        r0 = eng.submit(short, SamplingParams(max_tokens=10, seed=0))
        eng.step()  # short request is now DECODING
        r1 = eng.submit(long, SamplingParams(max_tokens=4, seed=1))
        return _drain_ordered(eng, [r0, r1])

    for got, want in zip(run(True), run(False)):
        np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("paged_impl", ["xla", "pallas_interpret"])
def test_spec_matches_plain_paged(rng, paged_impl):
    """Draft and verify both route per-row token counts through the
    paged kernel's scalar-prefetched q_lens: tokens must match plain
    decode under the same impl."""
    prompts = [_prompt(rng, 11), _prompt(rng, 6)]
    n_tok = 3 if paged_impl == "pallas_interpret" else 6
    spec_k = 2 if paged_impl == "pallas_interpret" else 3

    def run(spec):
        eng = _engine(spec, n_slots=2, spec_k=spec_k,
                      use_paged_kernel=True, paged_impl=paged_impl)
        rids = [eng.submit(p, SamplingParams(max_tokens=n_tok, seed=i))
                for i, p in enumerate(prompts)]
        return _drain_ordered(eng, rids)

    for got, want in zip(run(True), run(False)):
        np.testing.assert_array_equal(got, want)


# -- truncated drafts: packed path, rejections, accept-rate bound -------


def _spec_counters(eng):
    return eng.metrics_registry.snapshot()["counters"]


def test_spec_truncated_draft_parity_packed(rng):
    """draft_slices < total_slices: the draft model really is lossy (it
    proposes from truncated weights and gets drafts rejected), yet the
    output still matches the packed plain-decode stream token-exactly —
    the verify pass, not the draft, decides every emitted token."""
    prompts = [_prompt(rng, 13), _prompt(rng, 7)]

    def run(spec, **kw):
        eng = _engine(spec, n_slots=2, packed=True, **kw)
        rids = [eng.submit(p, SamplingParams(max_tokens=8, temperature=0.7,
                                             seed=i))
                for i, p in enumerate(prompts)]
        outs = _drain_ordered(eng, rids)
        return outs, _spec_counters(eng)

    want, _ = run(False)
    got, counters = run(True, draft_slices=2)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)
    assert counters["spec.proposed"] > 0
    assert counters["spec.accepted"] <= counters["spec.proposed"]
    # every live row emits its bonus token even when all drafts miss
    assert counters["spec.tokens"] > counters["spec.accepted"]


def test_spec_accept_rate_one_at_full_slices(rng):
    """draft_slices == total_slices means draft logits ARE the verify
    logits (same packed weights, same (key, step) sampler), so every
    proposed draft is accepted: accept rate exactly 1.0."""
    probe = _engine(False, packed=True)
    total = total_slices(probe.params)
    assert total >= 1  # packed tree must expose its slice count
    del probe

    eng = _engine(True, n_slots=2, packed=True, draft_slices=total)
    for i, s0 in enumerate((10, 6)):
        eng.submit(_prompt(rng, s0),
                   SamplingParams(max_tokens=7, temperature=0.5, seed=i))
    eng.drain()
    counters = _spec_counters(eng)
    assert counters["spec.proposed"] > 0
    assert counters["spec.accepted"] == counters["spec.proposed"]


def test_draft_slices_out_of_range_rejected(rng):
    """The engine validates draft_slices against the packed tree's
    actual slice count at construction, not steps into serving."""
    probe = _engine(False, packed=True)
    total = total_slices(probe.params)
    with pytest.raises(ValueError, match="draft_slices"):
        _engine(True, packed=True, draft_slices=total + 1)


# -- dispatch counts + trace events -------------------------------------


def test_spec_step_dispatch_count_and_trace(rng):
    """A speculative step issues exactly k_max + 1 model dispatches
    (k_max S=1 drafts + ONE verify over all k_max+1 positions) and emits
    one SPEC_ACCEPT event per live slot plus one DECODE_STEP per
    accepted token — so TTFT/TPOT derivations stay spec-agnostic."""
    eng = _engine(True, n_slots=2, spec_k=3)
    rid = eng.submit(_prompt(rng, 8), SamplingParams(max_tokens=9, seed=0))
    eng.step()  # prefill + first token
    c = eng.metrics_registry.counter("step.model_dispatches")

    st = eng.scheduler.slots[0]
    done = []
    while st.n_gen < st.req.n_tokens:
        remaining = st.req.n_tokens - st.n_gen
        k_max = min(3, remaining - 1)
        n_ev = len(eng.tracer)
        before = c.value
        done += list(eng.step())
        new = eng.tracer.events()[n_ev:]
        if k_max == 0:
            # last token: speculation degenerates to plain decode
            assert c.value - before == 1
            assert not [e for e in new if e.kind == tr.SPEC_ACCEPT]
            continue
        assert c.value - before == k_max + 1
        (acc,) = [e for e in new if e.kind == tr.SPEC_ACCEPT]
        assert acc.fields["proposed"] == k_max
        n_decode = len([e for e in new if e.kind == tr.DECODE_STEP])
        assert n_decode == acc.fields["tokens"] == acc.fields["accepted"] + 1

    (out,) = done
    assert out.rid == rid and len(out.tokens) == 9
    stats = eng.tracer.request_stats(rid)
    # decode_step continuity: every generated token after the first has
    # exactly one decode_step event, whatever the accept pattern was
    assert stats["n_decode_steps"] == 8
    assert "tpot_s" in stats


# -- geometry sweep: spec == plain across (spec_k, slices, block, len) --


def _spec_parity_one(spec_k, draft_slices, block_size, prompt_len):
    rng = np.random.default_rng(prompt_len * 37 + spec_k * 5 + block_size)
    prompts = [_prompt(rng, prompt_len), _prompt(rng, 4)]

    def run(spec):
        eng = _engine(spec, n_slots=2, spec_k=spec_k, block_size=block_size,
                      packed=True,
                      draft_slices=draft_slices if spec else None)
        rids = [eng.submit(p, SamplingParams(max_tokens=5, temperature=0.6,
                                             seed=i))
                for i, p in enumerate(prompts)]
        return _drain_ordered(eng, rids)

    for got, want in zip(run(True), run(False)):
        np.testing.assert_array_equal(got, want)


SWEEP = [(1, 1, 8, 9), (2, 2, 4, 13), (3, 3, 8, 21), (4, 2, 4, 6)]


@pytest.mark.parametrize("spec_k,draft_slices,block_size,prompt_len", SWEEP)
def test_spec_geometry_sweep(spec_k, draft_slices, block_size, prompt_len):
    """Deterministic fallback for the hypothesis sweep below — runs
    everywhere, covers spec_k from degenerate (1) past the budget (4 >
    max_tokens-1), heavily truncated drafts, and both block sizes."""
    _spec_parity_one(spec_k, draft_slices, block_size, prompt_len)


@pytest.mark.slow
@requires_hypothesis()
def test_spec_geometry_sweep_hypothesis():
    """Property form of the sweep when hypothesis is installed: any
    (spec_k, draft_slices, block_size, prompt_len) must be speculative /
    plain token-exact. draft_slices is drawn past the valid ceiling and
    clamped so the sweep leans on the truncated region without assuming
    the arch's slice count."""
    import hypothesis as hyp
    from hypothesis import strategies as st

    probe = _engine(False, packed=True)
    total = total_slices(probe.params)
    del probe

    @hyp.settings(max_examples=5, deadline=None,
                  suppress_health_check=list(hyp.HealthCheck))
    @hyp.given(spec_k=st.integers(min_value=1, max_value=4),
               draft=st.integers(min_value=1, max_value=6),
               block_size=st.sampled_from([4, 8]),
               prompt_len=st.integers(min_value=2, max_value=32))
    def prop(spec_k, draft, block_size, prompt_len):
        _spec_parity_one(spec_k, min(draft, total), block_size, prompt_len)

    prop()


# -- kernel-level keep_slices semantics ---------------------------------


@pytest.mark.parametrize("method", ["swis", "swis_c"])
def test_keep_slices_kernel_semantics(rng, method):
    """keep_slices truncates to the MOST significant planes (ascending
    shift layout: the last k planes): keep == n_shifts reproduces the
    full matmul bit-exactly, the dequant error decays monotonically as
    slices are added back, and the Pallas kernel path agrees with the
    jnp oracle at every truncation level."""
    k, n, group, n_shifts = 128, 128, 4, 4
    w = rng.normal(0, 0.05, (k, n)).astype(np.float32)
    qw = swis.quantize(jnp.asarray(w),
                       swis.QuantConfig(method=method, n_shifts=n_shifts,
                                        group_size=group))
    pw = packing.pack(qw)
    x = jnp.asarray(rng.normal(0, 1, (8, k)).astype(np.float32))
    consecutive = pw.method == "swis_c"

    full = np.asarray(ref.swis_matmul_ref(
        x, pw.sign_plane, pw.mask_planes, pw.shifts, pw.scale,
        group=group, consecutive=consecutive))
    w_full = np.asarray(ref.dequant_ref(
        pw.sign_plane, pw.mask_planes, pw.shifts, pw.scale, group=group,
        consecutive=consecutive))

    errs = []
    for keep in range(1, n_shifts + 1):
        w_k = np.asarray(ref.dequant_ref(
            pw.sign_plane, pw.mask_planes, pw.shifts, pw.scale,
            group=group, consecutive=consecutive, keep_slices=keep))
        errs.append(np.abs(w_k - w_full).mean())
        want = np.asarray(ref.swis_matmul_ref(
            x, pw.sign_plane, pw.mask_planes, pw.shifts, pw.scale,
            group=group, consecutive=consecutive, keep_slices=keep))
        got = np.asarray(ops.swis_matmul(x, pw, use_pallas=True,
                                         interpret=True, keep_slices=keep))
        np.testing.assert_allclose(got, want, rtol=1e-5,
                                   atol=1e-5 * max(np.abs(want).max(), 1.0))
    np.testing.assert_array_equal(
        np.asarray(ops.swis_matmul(x, pw, keep_slices=n_shifts)), full)
    assert errs[-1] == 0.0
    assert all(a >= b for a, b in zip(errs, errs[1:]))  # monotone decay


def test_keep_slices_validation(rng):
    qw = swis.quantize(
        jnp.asarray(rng.normal(0, 0.05, (64, 128)).astype(np.float32)),
        swis.QuantConfig(n_shifts=3, group_size=4))
    pw = packing.pack(qw)
    x = jnp.ones((4, 64), jnp.float32)
    for bad in (0, 4):
        with pytest.raises(ValueError, match="keep_slices"):
            ops.swis_matmul(x, pw, keep_slices=bad)


def test_keep_slices_vjp_uses_truncated_weights(rng):
    """The custom VJP backprops through the SAME truncated weights the
    forward used — the draft model's gradient story stays consistent
    with its forward (pinned here even though serving never uses it)."""
    qw = swis.quantize(
        jnp.asarray(rng.normal(0, 0.05, (128, 128)).astype(np.float32)),
        swis.QuantConfig(n_shifts=4, group_size=4))
    pw = packing.pack(qw)
    x = jnp.asarray(rng.normal(0, 1, (4, 128)).astype(np.float32))
    g = jax.grad(lambda xx: ops.swis_matmul(xx, pw, keep_slices=2).sum())(x)
    w_t = np.asarray(ref.dequant_ref(
        pw.sign_plane, pw.mask_planes, pw.shifts, pw.scale, group=4,
        keep_slices=2))
    np.testing.assert_allclose(np.asarray(g), np.ones((4, 128)) @ w_t.T,
                               rtol=1e-4, atol=1e-4)
