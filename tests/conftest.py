import jax
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def interpret_modes():
    """Parametrize Pallas kernel tests over interpret=True/False.

    interpret=True runs everywhere (pure-Python emulation). compiled mode
    (interpret=False) needs a backend with Pallas lowering support, so it
    is skipped gracefully on CPU CI and exercised on TPU runners.
    """
    compiled = pytest.param(
        False,
        id="compiled",
        marks=pytest.mark.skipif(
            jax.default_backend() not in ("tpu", "gpu"),
            reason="Pallas compile requires a TPU/GPU backend",
        ),
    )
    return [pytest.param(True, id="interpret"), compiled]
