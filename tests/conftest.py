import importlib.util

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def interpret_modes():
    """Parametrize Pallas kernel tests over interpret=True/False.

    interpret=True runs everywhere (pure-Python emulation). compiled mode
    (interpret=False) needs a backend with Pallas lowering support, so
    those params carry the ``pallas_compiled`` marker and the backend
    check happens lazily in :func:`pytest_runtest_setup` — collection
    never initializes the JAX backend just to decide a skip, and the
    skip reason names the backend that was actually found.
    """
    return [pytest.param(True, id="interpret"),
            pytest.param(False, id="compiled",
                         marks=pytest.mark.pallas_compiled)]


def requires_hypothesis():
    """Collection-time skip marker for hypothesis property tests.

    ``find_spec`` probes installability without importing, so tier-1
    environments without hypothesis skip these tests (instead of
    erroring) and pay no import cost at collection.
    """
    return pytest.mark.skipif(
        importlib.util.find_spec("hypothesis") is None,
        reason="hypothesis not installed")


def pytest_runtest_setup(item):
    if item.get_closest_marker("pallas_compiled") is not None:
        import jax

        backend = jax.default_backend()
        if backend not in ("tpu", "gpu"):
            pytest.skip(f"Pallas compile requires a TPU/GPU backend "
                        f"(default backend is {backend!r})")
