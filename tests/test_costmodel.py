"""Cost-model validation: predictions pinned against ground truth.

* gathered-bytes parity: the model's paged-decode gathered-K/V bytes
  equal the bench's measured ``decode_gathered_bytes_per_step`` for the
  gather / XLA-scan / Pallas paths — exactly, no tolerance;
* packed weight traffic equals ``pack_tree``'s own storage accounting
  (``packed_bits / 8``) — the §3.3 compression math appears once, used
  twice, and must agree;
* SWIS shift-pass cycles shrink strictly monotonically as
  ``draft_slices`` truncates bit-planes (and hit the full-precision
  count at ``keep_slices = n_shifts``);
* every dispatch kind the engine issues records its ``cost.<kind>.*``
  counters, and the utilization gauges are consistent with the recorded
  totals;
* the exported Chrome trace passes ``check_bench``'s schema smoke check
  with nested step -> phase spans for a fused mixed-load run;
* ``check_bench.attribute_regressions`` names the doctored phase and
  cost counter, and only those.
"""
import functools
import os
import sys

import jax
import numpy as np

import repro.configs as C
from repro.core.swis import QuantConfig
from repro.models import params as pp
from repro.models.model import Model
from repro.serve import (ContinuousBatchingEngine, EngineConfig,
                         SamplingParams)
from repro.serve.costmodel import GemmSpec, gemm_inventory

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "benchmarks"))
import check_bench  # noqa: E402
import serve_bench  # noqa: E402

MAX_LEN = 48
BS = 8
N_SHIFTS = 4


@functools.cache
def _setup():
    cfg = C.get_smoke("smollm-135m").replace(compute_dtype="float32")
    params = pp.init_params(Model(cfg).build(), jax.random.key(0))
    return cfg, params


def _engine(n_slots=2, **kw):
    cfg, params = _setup()
    kw.setdefault("prefix_cache", True)
    kw.setdefault("block_size", BS)
    return ContinuousBatchingEngine(
        cfg, params, config=EngineConfig(max_len=MAX_LEN, n_slots=n_slots,
                                         **kw))


def _packed_engine(**kw):
    qcfg = QuantConfig(method="swis", n_shifts=N_SHIFTS, group_size=4)
    return _engine(packed=True, quant_cfg=qcfg, **kw)


def _prompt(rng, n):
    cfg, _ = _setup()
    return rng.integers(0, cfg.vocab, (n,)).astype(np.int32)


def _drive(eng, rng, n_req=3, prompt_len=10, tokens=5, stagger=0):
    for i in range(n_req):
        eng.submit(_prompt(rng, prompt_len + i),
                   SamplingParams(max_tokens=tokens, seed=i))
        for _ in range(stagger):
            eng.step()
    eng.drain()


# ---------------------------------------------------------------------------
# Ground-truth parity
# ---------------------------------------------------------------------------


def test_gathered_bytes_matches_bench_measurement():
    """Predicted gathered-K/V bytes per decode step == the bench's
    measured ``decode_gathered_bytes_per_step``, for every paged
    backend — the acceptance pin, exact equality."""
    cfg, _ = _setup()
    variants = [dict(),  # gather reference (paged_impl None)
                dict(use_paged_kernel=True, paged_impl="xla"),
                dict(use_paged_kernel=True, paged_impl="pallas_interpret")]
    for kw in variants:
        eng = _engine(**kw)
        want = serve_bench._decode_gathered_bytes(eng, cfg)
        got = eng.cost_model.decode(eng.n_slots).gathered_bytes
        assert got == want, (kw, got, want)
        # the gathered copy is part of (never exceeds) the HBM total
        cost = eng.cost_model.decode(eng.n_slots)
        assert cost.hbm_bytes >= cost.gathered_bytes
        assert cost.hbm_bytes > 0 and cost.flops > 0


def test_contiguous_cache_never_gathers():
    eng = _engine(prefix_cache=False)
    assert eng.cost_model.decode(eng.n_slots).gathered_bytes == 0.0


def test_packed_weight_bytes_match_pack_tree_accounting():
    """The cost model's per-dispatch packed weight traffic must equal
    ``pack_tree``'s own stored-bits accounting: one compression formula,
    two consumers, zero drift."""
    eng = _packed_engine()
    packed_specs = [sp for sp in eng.cost_model.specs if sp.packed]
    assert len(packed_specs) == eng.pack_stats["n_packed"] > 0
    got = sum(sp.weight_bytes() for sp in packed_specs)
    want = eng.pack_stats["packed_bits"] / 8.0
    assert abs(got - want) < 1e-6, (got, want)
    # and the dense engine's GEMM inventory sees the same MAC count —
    # packing changes bytes, never arithmetic
    cfg, params = _setup()
    dense_specs, _ = gemm_inventory(params)
    assert (sum(sp.macs for sp in dense_specs)
            == sum(sp.macs for sp in eng.cost_model.specs))


def test_swis_cycles_strictly_monotone_in_draft_slices():
    """Truncating bit-planes must strictly reduce predicted shift-pass
    cycles, and keep_slices == n_shifts must equal full precision."""
    eng = _packed_engine()
    cm = eng.cost_model
    cycles = [cm.draft(2, keep_slices=k).swis_cycles
              for k in range(1, N_SHIFTS + 1)]
    assert all(a < b for a, b in zip(cycles, cycles[1:])), cycles
    assert cycles[-1] == cm.draft(2, keep_slices=None).swis_cycles
    # HBM weight traffic shrinks with truncation too (fewer mask planes)
    hbm = [cm.draft(2, keep_slices=k).hbm_bytes
           for k in range(1, N_SHIFTS + 1)]
    assert all(a < b for a, b in zip(hbm, hbm[1:])), hbm


def test_gemm_spec_weight_bytes_honors_truncation():
    sp = GemmSpec(k=64, c=32, packed=True, n_shifts=4, group_size=4)
    full = sp.weight_bytes()
    assert sp.weight_bytes(keep_slices=2) < full
    # clamped: keep beyond n_shifts is full precision, floor at 1 slice
    assert sp.weight_bytes(keep_slices=9) == full
    assert sp.weight_bytes(keep_slices=0) == sp.weight_bytes(keep_slices=1)


# ---------------------------------------------------------------------------
# Engine wiring: every dispatch kind records its cost
# ---------------------------------------------------------------------------


def _counters(eng):
    return eng.metrics_registry.snapshot()["counters"]


def test_decode_and_prefill_kinds_recorded(rng):
    eng = _engine()
    _drive(eng, rng)
    c = _counters(eng)
    for kind in ("decode", "prefill"):
        for field in ("flops", "hbm_bytes", "swis_cycles"):
            assert c.get(f"cost.{kind}.{field}", 0) > 0, (kind, field)
    # global totals are the sum of the per-kind totals
    for field in ("flops", "hbm_bytes", "swis_cycles"):
        per_kind = sum(v for k, v in c.items()
                       if k.startswith("cost.") and k.endswith(f".{field}")
                       and k.count(".") == 2)
        assert abs(c[f"cost.{field}"] - per_kind) < 1e-6


def test_chunk_and_mixed_kinds_recorded(rng):
    sep = _engine(prefill_chunk=BS)
    _drive(sep, rng, prompt_len=2 * BS + 3)
    assert _counters(sep).get("cost.chunk.flops", 0) > 0
    fused = _engine(prefill_chunk=BS, fused_step=True)
    _drive(fused, rng, prompt_len=2 * BS + 3)
    assert _counters(fused).get("cost.mixed.flops", 0) > 0


def test_spec_kinds_recorded_and_draft_cheaper(rng):
    eng = _packed_engine(spec_decode=True, spec_k=2, draft_slices=1)
    _drive(eng, rng, tokens=8)
    c = _counters(eng)
    assert c.get("cost.draft.swis_cycles", 0) > 0
    assert c.get("cost.verify.flops", 0) > 0
    # a truncated S=1 draft launch costs fewer SWIS cycles than the
    # full-precision k+1-position verify launch
    cm = eng.cost_model
    assert (cm.draft(eng.n_slots, keep_slices=1).swis_cycles
            < cm.verify(eng.n_slots, 3).swis_cycles)


def test_utilization_gauges_consistent(rng):
    eng = _engine()
    _drive(eng, rng)
    snap = eng.metrics_registry.snapshot()
    total = snap["histograms"]["step.total_s"]["sum"]
    assert total > 0
    want = snap["counters"]["cost.hbm_bytes"] / total
    assert abs(snap["gauges"]["cost.hbm_bytes_per_s"] - want) < 1e-6
    assert snap["gauges"]["cost.flops_per_s"] > 0


def test_cost_model_summary_in_metrics(rng):
    eng = _packed_engine()
    cm = eng.metrics()["engine"]["cost_model"]
    assert cm["n_packed_leaves"] == eng.pack_stats["n_packed"]
    # N=4/group-4 SWIS stores exactly 8 bits/weight, so packed traffic
    # can match but never exceed the 8-bit dense reference...
    assert cm["weight_bytes_per_dispatch"] <= cm["weight_bytes_dense8"]
    # ...and is far below what the unpacked fp32 engine streams
    dense = _engine().metrics()["engine"]["cost_model"]
    assert (cm["weight_bytes_per_dispatch"]
            < dense["weight_bytes_per_dispatch"])
    assert cm["gemm_flops_per_token"] > 0


def test_costs_deterministic_across_reset(rng):
    """Same traffic -> bit-identical cost counters after reset: the cost
    layer is a pure function of the dispatch pattern."""
    eng = _engine(prefill_chunk=BS, fused_step=True)
    state = rng.bit_generator.state
    _drive(eng, rng, prompt_len=2 * BS + 3)
    first = {k: v for k, v in _counters(eng).items()
             if k.startswith("cost.")}
    assert first
    eng.reset()
    rng.bit_generator.state = state
    _drive(eng, rng, prompt_len=2 * BS + 3)
    second = {k: v for k, v in _counters(eng).items()
              if k.startswith("cost.")}
    assert first == second


# ---------------------------------------------------------------------------
# Chrome-trace schema + regression attribution (check_bench contracts)
# ---------------------------------------------------------------------------


def test_chrome_trace_passes_schema_check_for_mixed_run(rng, tmp_path):
    """A fused mixed-load-style run exports a Chrome trace that passes
    the CI schema smoke check and contains nested step -> mixed_dispatch
    spans."""
    eng = _engine(prefill_chunk=BS, fused_step=True, n_slots=2)
    _drive(eng, rng, n_req=3, prompt_len=2 * BS + 3, tokens=6, stagger=1)
    path = str(tmp_path / "chrome_trace_mixed_load.json")
    eng.tracer.export_chrome_trace(path)
    assert check_bench.check_chrome_trace(path) == []
    import json
    with open(path) as f:
        events = json.load(f)["traceEvents"]
    steps = [e for e in events if e["ph"] == "X" and e["name"] == "step"]
    mixed = [e for e in events if e["ph"] == "X"
             and e["name"] == "mixed_dispatch"]
    assert steps and mixed
    assert any(s["ts"] <= mx["ts"] and mx["ts"] + mx["dur"]
               <= s["ts"] + s["dur"] + 1e-6
               for mx in mixed for s in steps)


def test_chrome_trace_schema_check_rejects_broken_trace(tmp_path):
    p = str(tmp_path / "bad.json")
    with open(p, "w") as f:
        f.write("{not json")
    assert check_bench.check_chrome_trace(p)
    import json
    with open(p, "w") as f:
        json.dump({"traceEvents": [{"ph": "X", "ts": 0, "pid": 1,
                                    "name": "admit", "dur": 1}]}, f)
    errs = check_bench.check_chrome_trace(p)
    assert errs and "no 'step' span" in errs[0]


def test_attribution_names_the_doctored_phase_and_counter():
    """An injected per-phase regression / cost drift is attributed to
    exactly the phase and counter that moved."""
    baseline = {"mixed_load": {
        "tok_per_s": 100.0, "p95_step_s": 0.02,
        "phases": {"step.total_s": 0.020, "step.mixed_dispatch_s": 0.010,
                   "step.sample_host_s": 0.002},
        "cost": {"cost.flops": 1e9, "cost.hbm_bytes": 1e8}}}
    results = {"mixed_load": {
        "phases": {"step.total_s": 0.021,          # within tolerance
                   "step.mixed_dispatch_s": 0.050,  # doctored: 5x
                   "step.sample_host_s": 0.002},
        "cost": {"cost.flops": 2e9,                 # doctored: 2x
                 "cost.hbm_bytes": 1.01e8}}}        # within tolerance
    errs = check_bench.attribute_regressions(results, baseline,
                                             tolerance=0.25)
    assert len(errs) == 2, errs
    assert any("step.mixed_dispatch_s" in e and "regressed" in e
               for e in errs)
    assert any("cost.flops" in e and "moved" in e for e in errs)
    assert not any("step.total_s" in e or "cost.hbm_bytes" in e
                   for e in errs)
    # a clean run attributes nothing
    assert check_bench.attribute_regressions(
        {"mixed_load": baseline["mixed_load"]}, baseline, 0.25) == []


def test_attribution_flags_missing_phase_and_counter():
    baseline = {"w": {"phases": {"step.total_s": 0.01},
                      "cost": {"cost.flops": 1e9}}}
    errs = check_bench.attribute_regressions(
        {"w": {"phases": {}, "cost": {}}}, baseline, 0.25)
    assert len(errs) == 2
    assert any("absent" in e and "step.total_s" in e for e in errs)
    assert any("absent" in e and "cost.flops" in e for e in errs)


def test_bench_report_carries_phases_and_cost(rng):
    """serve_bench's per-pass report exposes the attribution surface:
    p95 per phase histogram, global cost counters."""
    cfg, params = _setup()
    rep = serve_bench.run_workload(
        "uniform", cfg, params, n_slots=2, requests=3, packed=False,
        qcfg=None, block_size=BS, passes=1)
    assert rep["phases"].get("step.total_s", 0) > 0
    assert all(k.endswith("_s") for k in rep["phases"])
    assert rep["cost"].get("cost.flops", 0) > 0
    assert set(rep["cost"]) >= {"cost.flops", "cost.hbm_bytes",
                                "cost.swis_cycles"}
    # per-kind counters (dotted twice) stay out of the compact report
    assert not any(k.count(".") > 1 for k in rep["cost"])


def test_cost_model_memoizes_launch_shapes():
    eng = _engine()
    cm = eng.cost_model
    a = cm.decode(2)
    assert cm.decode(2) is a  # memoized, no per-step allocation
    assert cm.decode(1) is not a
