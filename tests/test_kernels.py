"""Pallas SWIS matmul kernel: shape/dtype/group/shift sweep vs the pure-jnp
oracle and vs dense fake-quant (exact same function)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import packing, swis
from repro.kernels import ops, ref
from conftest import interpret_modes

SWEEP = [
    # (M, K, N, group, n_shifts, dtype)
    (8, 128, 128, 4, 2, jnp.float32),
    (16, 256, 256, 8, 3, jnp.float32),
    (32, 512, 128, 4, 4, jnp.float32),
    (8, 64, 256, 16, 5, jnp.float32),
    (8, 128, 128, 4, 3, jnp.bfloat16),
    (4, 96, 128, 4, 3, jnp.float32),  # K not multiple of default bk
]


def _make(rng, k, n, group, n_shifts):
    w = rng.normal(0, 0.05, (k, n)).astype(np.float32)
    qw = swis.quantize(jnp.asarray(w),
                       swis.QuantConfig(n_shifts=n_shifts, group_size=group))
    return qw, packing.pack(qw)


@pytest.mark.parametrize("interpret", interpret_modes())
@pytest.mark.parametrize("m,k,n,group,n_shifts,dtype", SWEEP)
def test_pallas_matches_oracle(rng, m, k, n, group, n_shifts, dtype,
                               interpret):
    qw, pw = _make(rng, k, n, group, n_shifts)
    x = jnp.asarray(rng.normal(0, 1, (m, k)), dtype)
    want = np.asarray(ref.swis_matmul_ref(
        x, pw.sign_plane, pw.mask_planes, pw.shifts, pw.scale,
        group=group), np.float32)
    got = np.asarray(ops.swis_matmul(x, pw, use_pallas=True,
                                     interpret=interpret))
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * np.abs(want).max())


@pytest.mark.parametrize("m,k,n,group,n_shifts,dtype", SWEEP[:4])
def test_oracle_matches_fake_quant(rng, m, k, n, group, n_shifts, dtype):
    # packed matmul == x @ fake_quant(w): the paper's Eq. 7 equivalence
    w = rng.normal(0, 0.05, (k, n)).astype(np.float32)
    qw = swis.quantize(jnp.asarray(w),
                       swis.QuantConfig(n_shifts=n_shifts, group_size=group))
    pw = packing.pack(qw)
    x = jnp.asarray(rng.normal(0, 1, (m, k)), dtype)
    got = np.asarray(ops.swis_matmul(x, pw))
    want = np.asarray(x @ qw.qweights.astype(dtype), np.float32)
    np.testing.assert_allclose(got, want, rtol=1e-5,
                               atol=1e-5 * np.abs(want).max())


@pytest.mark.parametrize("interpret", interpret_modes())
@pytest.mark.parametrize("n_shifts", [2, 3, 4])
def test_swis_c_offset_packed(rng, n_shifts, interpret):
    # SWIS-C stores one offset byte per group (paper §2.2 compression edge)
    w = rng.normal(0, 0.05, (256, 128)).astype(np.float32)
    qw = swis.quantize(jnp.asarray(w),
                       swis.QuantConfig(method="swis_c", n_shifts=n_shifts,
                                        group_size=4))
    pw = packing.pack(qw)
    assert pw.shifts.shape[-1] == 1 and pw.method == "swis_c"
    x = jnp.asarray(rng.normal(0, 1, (8, 256)).astype(np.float32))
    want = np.asarray(x @ qw.qweights)
    for use_pallas in (False, True):
        got = np.asarray(ops.swis_matmul(x, pw, use_pallas=use_pallas,
                                         interpret=interpret))
        np.testing.assert_allclose(got, want, rtol=1e-5,
                                   atol=1e-5 * np.abs(want).max())


def test_higher_rank_input(rng):
    qw, pw = _make(rng, 128, 64, 4, 3)
    x = jnp.asarray(rng.normal(0, 1, (2, 5, 128)).astype(np.float32))
    y = ops.swis_matmul(x, pw)
    assert y.shape == (2, 5, 64)


def test_custom_vjp(rng):
    qw, pw = _make(rng, 128, 128, 4, 3)
    x = jnp.asarray(rng.normal(0, 1, (4, 128)).astype(np.float32))
    g = jax.grad(lambda xx: ops.swis_matmul(xx, pw).sum())(x)
    want = np.ones((4, 128)) @ np.asarray(qw.qweights).T
    np.testing.assert_allclose(np.asarray(g), want, rtol=1e-4)


def test_tile_shape_validation(rng):
    qw, pw = _make(rng, 128, 128, 4, 3)
    x = jnp.ones((8, 128), jnp.float32)
    from repro.kernels.swis_matmul import swis_matmul_packed

    with pytest.raises(ValueError):
        swis_matmul_packed(x, pw.sign_plane, pw.mask_planes, pw.shifts,
                           pw.scale, n_shifts=3, group=4, bm=8, bn=128,
                           bk=48)  # bk not a multiple of 32


# ---------------------------------------------------------------------------
# Parametrized kernel sweep: consecutive (SWIS-C) x n_shifts x tile shapes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("interpret", interpret_modes())
@pytest.mark.parametrize("consecutive", [False, True])
@pytest.mark.parametrize("n_shifts", [1, 2, 3])
@pytest.mark.parametrize("bm,bn,bk", [(8, 128, 64), (16, 128, 32)])
def test_packed_kernel_param_sweep(rng, consecutive, n_shifts, bm, bn, bk,
                                   interpret):
    m, k, n, group = 16, 128, 128, 4
    method = "swis_c" if consecutive else "swis"
    w = rng.normal(0, 0.05, (k, n)).astype(np.float32)
    qw = swis.quantize(jnp.asarray(w),
                       swis.QuantConfig(method=method, n_shifts=n_shifts,
                                        group_size=group))
    pw = packing.pack(qw)
    assert (pw.method == "swis_c") == consecutive
    x = jnp.asarray(rng.normal(0, 1, (m, k)).astype(np.float32))
    want = np.asarray(ref.swis_matmul_ref(
        x, pw.sign_plane, pw.mask_planes, pw.shifts, pw.scale,
        group=group, consecutive=consecutive))
    from repro.kernels.swis_matmul import swis_matmul_packed

    got = np.asarray(swis_matmul_packed(
        x, pw.sign_plane, pw.mask_planes, pw.shifts, pw.scale,
        n_shifts=n_shifts, group=group, bm=bm, bn=bn, bk=bk,
        interpret=interpret, consecutive=consecutive))
    np.testing.assert_allclose(got, want, rtol=1e-5,
                               atol=1e-5 * max(np.abs(want).max(), 1.0))


@pytest.mark.parametrize("kwargs,match", [
    # bk=48: not a multiple of 32 (divides k=192 so the shape check passes)
    (dict(bm=8, bn=128, bk=48), "multiple of 32"),
    # bk=64 is a multiple of 32 but not of group=48
    (dict(bm=8, bn=128, bk=64, group=48), "group"),
    # k=192 is not divisible by bk=160
    (dict(bm=8, bn=128, bk=160), "not divisible"),
    (dict(bm=5, bn=128, bk=192), "not divisible"),  # m=8 % bm=5
])
def test_tile_error_paths(rng, kwargs, match):
    from repro.kernels.swis_matmul import swis_matmul_packed

    k, n, n_shifts = 192, 128, 3
    group = kwargs.pop("group", 4)
    x = jnp.ones((8, k), jnp.float32)
    sign = jnp.zeros((k // 32, n), jnp.uint32)
    mask = jnp.zeros((n_shifts, k // 32, n), jnp.uint32)
    shifts = jnp.zeros((k // group, n, (n_shifts + 1) // 2), jnp.uint8)
    scale = jnp.ones((1, n), jnp.float32)
    with pytest.raises(ValueError, match=match):
        swis_matmul_packed(x, sign, mask, shifts, scale, n_shifts=n_shifts,
                           group=group, **kwargs)


# ---------------------------------------------------------------------------
# _pick_tiles: the launcher's tile-shape heuristic
# ---------------------------------------------------------------------------


def test_pick_tiles_divisibility_invariants():
    from repro.kernels.ops import _pick_tiles

    for m, k, n, group in [(128, 512, 256, 4), (16, 256, 128, 8),
                           (8, 64, 256, 16), (4, 96, 128, 4)]:
        bm, bn, bk = _pick_tiles(m, k, n, group)
        assert m % bm == 0 and n % bn == 0 and k % bk == 0
        assert bk % group == 0


def test_pick_tiles_odd_prime_dims():
    from repro.kernels.ops import _pick_tiles

    # prime dims: bm degrades to the 1-row candidate, bn/bk fall back to
    # the full dimension (still valid: whole-axis single tile)
    bm, bn, bk = _pick_tiles(7, 97, 13, 1)
    assert (bm, bn, bk) == (1, 13, 97)


def test_pick_tiles_group_forces_bk_fallback():
    from repro.kernels.ops import _pick_tiles

    # group=3 divides k=96 but no power-of-two candidate, so bk must fall
    # back to the whole K dimension
    bm, bn, bk = _pick_tiles(8, 96, 128, 3)
    assert bk == 96 and bk % 3 == 0
    # and a group that divides the halved candidates keeps the tile small
    _, _, bk2 = _pick_tiles(8, 1024, 128, 4)
    assert bk2 == 512
