"""Data pipeline, optimizer, checkpointing, fault-tolerant loop, serving."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.checkpoint import CheckpointManager
from repro.core.swis import QuantConfig
from repro.data import SyntheticPipeline
from repro.models import params as pp
from repro.models.model import Model
from repro.optim import AdamW, clip_by_global_norm, global_norm, warmup_cosine
from repro.optim.compress import dequantize_grads, quantize_grads_int8
from repro.serve import DecodeEngine, pack_tree
from repro.train.loop import SimulatedFailure, Trainer


def test_pipeline_host_slicing():
    cfg = C.get_smoke("smollm-135m")
    full = SyntheticPipeline(cfg, 16, 8, seed=1)
    b = full.batch_at(3)
    parts = []
    for h in range(4):
        p = SyntheticPipeline(cfg, 16, 8, seed=1, n_hosts=4, host_id=h)
        parts.append(p.host_slice(p.batch_at(3))["tokens"])
    np.testing.assert_array_equal(np.concatenate(parts), b["tokens"])


def test_adamw_converges_quadratic():
    opt = AdamW(weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    target = jnp.asarray([1.0, 2.0])
    for step in range(300):
        g = {"w": 2 * (params["w"] - target)}
        params, state = opt.update(g, state, params, lr=0.05,
                                   step=jnp.int32(step))
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_clip_and_schedule():
    tree = {"a": jnp.full((10,), 3.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    lr = warmup_cosine(1e-3, 10, 100)
    assert float(lr(0)) < float(lr(9))
    assert float(lr(99)) < float(lr(10))


def test_grad_compression_roundtrip(rng):
    g = {"w": jnp.asarray(rng.normal(0, 1e-3, (64, 64)).astype(np.float32))}
    q, s = quantize_grads_int8(g)
    assert q["w"].dtype == jnp.int8
    deq = dequantize_grads(q, s)
    rel = float(jnp.abs(deq["w"] - g["w"]).max() / jnp.abs(g["w"]).max())
    assert rel < 0.01  # 127-level quantization of a well-scaled leaf


def test_checkpoint_roundtrip_retention_async():
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d, keep=2)
        tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4)}}
        for step in (1, 2, 3):
            cm.save(step, tree, meta={"data": {"step": step}},
                    blocking=(step != 3))
        cm.wait()
        assert cm.all_steps() == [2, 3]  # retention
        template = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
        restored, meta = cm.restore(template)
        assert meta["step"] == 3
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.asarray(tree["a"]))
        # atomicity: no tmp dirs left behind
        assert not [f for f in os.listdir(d) if f.endswith(".tmp")]


def test_train_loss_decreases_and_restart_bitexact():
    cfg = C.get_smoke("smollm-135m")
    with tempfile.TemporaryDirectory() as d:
        a = Trainer(cfg, seq_len=32, global_batch=8,
                    workdir=os.path.join(d, "a"), total_steps=10,
                    ckpt_every=4, warmup=2, peak_lr=1e-2)
        out_a = a.run(10)
        assert out_a["last_loss"] < out_a["first_loss"] + 0.1
        b1 = Trainer(cfg, seq_len=32, global_batch=8,
                     workdir=os.path.join(d, "b"), total_steps=10,
                     ckpt_every=4, warmup=2, peak_lr=1e-2, fail_at_step=6)
        with pytest.raises(SimulatedFailure):
            b1.run(10)
        b2 = Trainer(cfg, seq_len=32, global_batch=8,
                     workdir=os.path.join(d, "b"), total_steps=10,
                     ckpt_every=4, warmup=2, peak_lr=1e-2)
        out_b = b2.run(10)
        diffs = jax.tree.map(lambda x, y: float(jnp.abs(x - y).max()),
                             out_a["state"].params, out_b["state"].params)
        assert max(jax.tree.leaves(diffs)) == 0.0


def test_straggler_deadline_counter():
    cfg = C.get_smoke("smollm-135m")
    tr = Trainer(cfg, seq_len=32, global_batch=8, total_steps=3, warmup=1,
                 step_deadline_s=1e-9)  # everything is a straggler
    out = tr.run(3)
    assert out["straggler_events"] >= 2


def test_packed_serving_matches_fake_quant(rng):
    cfg = C.get_smoke("phi3-mini-3.8b").replace(compute_dtype="float32")
    m = Model(cfg)
    params = pp.init_params(m.build(), jax.random.key(0))
    qcfg = QuantConfig(n_shifts=4, group_size=4)
    packed, stats = pack_tree(params, qcfg)
    assert stats["n_packed"] > 0
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 12)),
                                   jnp.int32)}
    lp, _, _ = m.apply(packed, batch)
    # dense PTQ fake-quant path — mathematically the same function
    from benchmarks.common import quant_policy

    cfg_q = cfg.replace(quant=quant_policy("swis", 4))
    lq, _, _ = Model(cfg_q).apply(params, batch)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lq), rtol=2e-3,
                               atol=2e-3 * float(jnp.abs(lq).max()))


def test_packed_moe_experts_match_fake_quant(rng):
    # regression: stacked (L, E, K, C) 4-D expert weights must pack too
    import dataclasses

    from benchmarks.common import quant_policy

    cfg = C.get_smoke("qwen2-moe-a2.7b").replace(compute_dtype="float32")
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, d_ff_expert=64),
                      d_ff=64)
    m = Model(cfg)
    params = pp.init_params(m.build(), jax.random.key(0))
    packed, stats = pack_tree(params, QuantConfig(n_shifts=4, group_size=4))
    assert stats["n_packed"] >= 10  # includes the 4-D expert stacks
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 12)),
                                   jnp.int32)}
    lp, _, _ = m.apply(packed, batch)
    lq, _, _ = Model(cfg.replace(quant=quant_policy("swis", 4))).apply(
        params, batch)
    err = float(jnp.abs(lp - lq).max() / jnp.abs(lq).max())
    assert err < 1e-4


@pytest.mark.parametrize("arch", ["smollm-135m", "mamba2-2.7b",
                                  "recurrentgemma-2b", "qwen2-moe-a2.7b"])
def test_decode_engine_generates(rng, arch):
    # engine-level generation across cache families (ring KV, SSD state,
    # RG-LRU state + windowed ring, MoE dropless decode)
    cfg = C.get_smoke(arch).replace(compute_dtype="float32")
    params = pp.init_params(Model(cfg).build(), jax.random.key(0))
    eng = DecodeEngine(cfg, params, max_len=32, batch=2)
    prompt = rng.integers(0, cfg.vocab, (2, 5)).astype(np.int32)
    out = eng.generate(prompt, 8)
    assert out.shape == (2, 13)
    np.testing.assert_array_equal(out[:, :5], prompt)
    assert out.min() >= 0 and out.max() < cfg.padded_vocab


def test_decode_engine_swis_c_packed(rng):
    cfg = C.get_smoke("phi3-mini-3.8b").replace(compute_dtype="float32")
    params = pp.init_params(Model(cfg).build(), jax.random.key(0))
    eng = DecodeEngine(cfg, params, max_len=24, batch=2, packed=True,
                       quant_cfg=QuantConfig(method="swis_c", n_shifts=4,
                                             group_size=4))
    prompt = rng.integers(0, cfg.vocab, (2, 4)).astype(np.int32)
    out = eng.generate(prompt, 6)
    assert out.shape == (2, 10)
    # SWIS-C stores one offset byte per group
    leaf = eng.params["blocks"]["sub0_attn"]["mlp"]["wi"]["w"]
    assert leaf["shifts"].shape[-1] == 1
