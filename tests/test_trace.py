"""Trace semantics + engine-level observability integration.

Pins the request-lifecycle contract of `repro.serve.trace`:

  * TTFT is exactly (first_token ts - submit ts); queue wait exactly
    (admit ts - submit ts); TPOT the mean decode-step delta;
  * events are strictly ordered per rid (lifecycle phases never regress,
    timestamps never decrease) — including under chunked prefill and
    prefix hits;
  * JSONL export round-trips bit-exactly (TraceWriter -> parse -> the
    same events);
  * `engine.metrics()` is one unified snapshot, `prefix_stats()` is a
    view of it, and `engine.reset()` clears metrics + trace so
    back-to-back bench runs on one engine start from clean counters;
  * scheduler gauges survive `unadmit()` under pool starvation with no
    drift vs a recount.
"""
import functools

import jax
import numpy as np

import repro.configs as C
from repro.models import params as pp
from repro.models.model import Model
from repro.serve import (ContinuousBatchingEngine, EngineConfig,
                         SamplingParams)
from repro.serve import trace as tr
from repro.serve.trace import read_jsonl

MAX_LEN = 48
BS = 8

# lifecycle phase rank per event kind: per-rid streams must never regress
# (UNADMIT shares ADMIT's rank — a starved request legitimately bounces)
_PHASE = {tr.SUBMIT: 0, tr.ADMIT: 1, tr.UNADMIT: 1, tr.PREFIX_HIT: 1,
          tr.PREFILL_CHUNK: 2, tr.FIRST_TOKEN: 3, tr.DECODE_STEP: 4,
          tr.FINISH: 5}


@functools.cache
def _setup():
    cfg = C.get_smoke("smollm-135m").replace(compute_dtype="float32")
    params = pp.init_params(Model(cfg).build(), jax.random.key(0))
    return cfg, params


def _engine(n_slots=2, **kw):
    cfg, params = _setup()
    return ContinuousBatchingEngine(cfg, params,
                                    config=EngineConfig(max_len=MAX_LEN,
                                                        n_slots=n_slots,
            prefix_cache=True, block_size=BS, **kw))


def _prompt(rng, n):
    cfg, _ = _setup()
    return rng.integers(0, cfg.vocab, (n,)).astype(np.int32)


def _assert_ordered(events):
    assert events, "rid left no events"
    kinds = [e.kind for e in events]
    assert kinds[0] == tr.SUBMIT and kinds[-1] == tr.FINISH
    ts = [e.ts for e in events]
    assert ts == sorted(ts), "timestamps regressed"
    # a re-admission after unadmit may legally repeat phase 1; other
    # than that bounce, the lifecycle only moves forward
    ranks = [_PHASE[k] for k in kinds]
    for a, b in zip(ranks, ranks[1:]):
        assert b >= a or b == 1, (kinds, "lifecycle regressed")


# ---------------------------------------------------------------------------
# Derived-interval semantics
# ---------------------------------------------------------------------------


def test_ttft_tpot_queue_wait_from_raw_events(rng):
    eng = _engine()
    rid = eng.submit(_prompt(rng, 10), SamplingParams(max_tokens=6))
    eng.drain()
    evs = eng.tracer.events(rid)
    _assert_ordered(evs)
    first_of = {}
    for e in evs:
        first_of.setdefault(e.kind, e)
    stats = eng.tracer.request_stats(rid)
    assert stats["ttft_s"] == (first_of[tr.FIRST_TOKEN].ts
                               - first_of[tr.SUBMIT].ts)
    assert stats["queue_wait_s"] == (first_of[tr.ADMIT].ts
                                     - first_of[tr.SUBMIT].ts)
    dec = [e for e in evs if e.kind == tr.DECODE_STEP]
    # 6 generated tokens: first from prefill, 5 from decode steps
    assert len(dec) == 5 and stats["n_decode_steps"] == 5
    assert stats["tpot_s"] == ((dec[-1].ts - first_of[tr.FIRST_TOKEN].ts)
                               / len(dec))
    # decode steps carry their fold-in step index, strictly increasing
    assert [e.fields["step"] for e in dec] == list(range(1, 6))


def test_interleaved_requests_each_strictly_ordered(rng):
    eng = _engine(n_slots=2)
    rids = []
    for i in range(5):  # more requests than slots: recycling + queueing
        rids.append(eng.submit(_prompt(rng, 4 + 3 * i),
                               SamplingParams(max_tokens=4 + i, seed=i)))
        eng.step()
    eng.drain()
    for rid in rids:
        _assert_ordered(eng.tracer.events(rid))
    summ = eng.tracer.summary()
    assert summ["requests"] == 5 and summ["dropped"] == 0
    assert summ["ttft_s"]["n"] == 5 and summ["tpot_s"]["n"] == 5


def test_chunked_prefill_and_prefix_hit_events(rng):
    eng = _engine(n_slots=2, prefill_chunk=BS)
    base = _prompt(rng, 2 * BS + 3)
    r1 = eng.submit(base, SamplingParams(max_tokens=4, seed=0))
    eng.drain()  # commits base's blocks
    tail = np.concatenate([base, _prompt(rng, 5)])
    r2 = eng.submit(tail, SamplingParams(max_tokens=4, seed=1))
    eng.drain()
    evs1, evs2 = eng.tracer.events(r1), eng.tracer.events(r2)
    _assert_ordered(evs1)
    _assert_ordered(evs2)
    # r1: no cached prefix -> ceil((2*BS+3)/BS) = 3 chunks, no prefix_hit
    assert sum(e.kind == tr.PREFILL_CHUNK for e in evs1) == 3
    assert not any(e.kind == tr.PREFIX_HIT for e in evs1)
    # r2: 2 blocks cached -> prefix_hit(blocks=2), suffix of 8 -> 1 chunk
    hit = next(e for e in evs2 if e.kind == tr.PREFIX_HIT)
    assert hit.fields["blocks"] == 2 and hit.fields["tokens"] == 2 * BS
    assert sum(e.kind == tr.PREFILL_CHUNK for e in evs2) == 1
    assert eng.tracer.request_stats(r2)["prefix_hit_blocks"] == 2


def test_jsonl_roundtrip_same_events(rng, tmp_path):
    eng = _engine(n_slots=2, prefill_chunk=BS)
    base = _prompt(rng, 2 * BS + 3)
    for i in range(3):
        eng.submit(np.concatenate([base, _prompt(rng, 3 + i)]),
                   SamplingParams(max_tokens=5, seed=i))
        eng.step()
    eng.drain()
    events = eng.tracer.events()
    assert {e.kind for e in events} >= {tr.SUBMIT, tr.ADMIT, tr.PREFIX_HIT,
                                        tr.PREFILL_CHUNK, tr.FIRST_TOKEN,
                                        tr.DECODE_STEP, tr.FINISH}
    path = str(tmp_path / "trace.jsonl")
    n = eng.tracer.export_jsonl(path)
    assert n == len(events)
    back = read_jsonl(path)
    assert back == events  # bit-exact: kinds, rids, ts floats, fields
    # wall-clock stamps ride along and preserve the monotonic deltas
    with open(path) as f:
        import json as _json
        walls = [_json.loads(ln)["ts_wall"] for ln in f]
    assert walls == sorted(walls)


def test_trace_ring_is_bounded(rng):
    eng = _engine(trace_capacity=16)
    for i in range(3):
        eng.submit(_prompt(rng, 6), SamplingParams(max_tokens=8, seed=i))
    eng.drain()
    assert len(eng.tracer) == 16
    assert eng.tracer.dropped > 0
    assert eng.metrics()["trace"]["dropped"] == eng.tracer.dropped


def test_trace_ring_overflow_drop_count_exact():
    """`dropped` counts exactly the events pushed beyond capacity, and
    the ring retains exactly the newest `capacity` events."""
    t = tr.RequestTracer(capacity=4)
    for i in range(11):
        t.event(tr.DECODE_STEP, rid=0, step=i)
    assert len(t) == 4 and t.dropped == 7
    assert [e.fields["step"] for e in t.events()] == [7, 8, 9, 10]
    t.reset()
    assert len(t) == 0 and t.dropped == 0


def test_trace_ring_overflow_degrades_gracefully():
    """When a request's submit/admit events have been evicted, the
    derived stats lose exactly the intervals that needed them — no crash,
    no fabricated TTFT — and summary() still aggregates what remains."""
    t = tr.RequestTracer(capacity=8)
    t.event(tr.SUBMIT, rid=1, ts=0.0, prompt_len=4, n_tokens=6)
    t.event(tr.ADMIT, rid=1, ts=1.0, slot=0)
    t.event(tr.FIRST_TOKEN, rid=1, ts=2.0, slot=0)
    # 8 more events evict submit/admit/first_token out of the ring
    for j in range(7):
        t.event(tr.DECODE_STEP, rid=1, ts=3.0 + j, slot=0, step=1 + j)
    t.event(tr.FINISH, rid=1, ts=11.0, n_tokens=6)
    assert t.dropped == 3
    stats = t.request_stats(1)
    assert "ttft_s" not in stats and "queue_wait_s" not in stats
    assert "tpot_s" not in stats  # first_token evicted too
    assert stats["n_decode_steps"] == 7
    summ = t.summary()
    assert summ["requests"] == 1 and summ["dropped"] == 3
    assert summ["ttft_s"] == {} and summ["queue_wait_s"] == {}


def test_span_ring_bounded_separately_from_lifecycle():
    """Phase spans live in their own ring: span spam can never evict
    lifecycle events, and span overflow is counted separately."""
    t = tr.RequestTracer(capacity=4)
    t.event(tr.SUBMIT, rid=7, ts=0.0)
    for i in range(9):
        t.span("decode_dispatch", ts=float(i), dur=0.5)
    assert len(t) == 1 and t.dropped == 0  # lifecycle ring untouched
    assert len(t.spans()) == 4 and t.dropped_spans == 5
    assert [s.ts for s in t.spans()] == [5.0, 6.0, 7.0, 8.0]
    t.reset()
    assert t.spans() == [] and t.dropped_spans == 0


def test_engine_spans_nest_under_step_and_reset_clears(rng):
    eng = _engine()
    eng.submit(_prompt(rng, 10), SamplingParams(max_tokens=5))
    eng.drain()
    steps = eng.tracer.spans("step")
    assert steps and len(steps) == \
        eng.metrics_registry.counter("step.count").value
    # every non-step span falls inside some step span's interval, and
    # carries the step number it ran under
    for s in eng.tracer.spans():
        if s.name == "step":
            continue
        assert any(p.ts <= s.ts and s.ts + s.dur <= p.ts + p.dur + 1e-9
                   for p in steps), s.name
    assert {s.name for s in eng.tracer.spans()} >= {
        "step", "admit", "decode_dispatch", "sample_host"}
    m = eng.metrics()
    assert m["trace"]["spans"] == len(eng.tracer.spans())
    eng.reset()
    assert eng.tracer.spans() == [] and eng.tracer.dropped_spans == 0


def test_disabled_tracer_records_no_spans(rng):
    eng = _engine(enable_metrics=False)
    eng.submit(_prompt(rng, 8), SamplingParams(max_tokens=4))
    eng.drain()
    assert eng.tracer.spans() == [] and len(eng.tracer) == 0


def test_chrome_trace_export_schema(rng, tmp_path):
    """Exported Chrome trace: every event carries ph/ts/pid, step spans
    exist with phase spans nested inside, lifecycle instants and flow
    arrows ride the request track."""
    import json
    eng = _engine()
    rid = eng.submit(_prompt(rng, 10), SamplingParams(max_tokens=5))
    eng.drain()
    path = str(tmp_path / "trace.json")
    n = eng.tracer.export_chrome_trace(path)
    with open(path) as f:
        doc = json.load(f)
    events = doc["traceEvents"]
    assert n == len(events) > 0
    assert all(("ph" in e and "ts" in e and "pid" in e) for e in events)
    xs = [e for e in events if e["ph"] == "X"]
    steps = [e for e in xs if e["name"] == "step"]
    assert steps
    phases = [e for e in xs if e["pid"] == steps[0]["pid"]
              and e["name"] != "step"]
    assert any(s["ts"] <= p["ts"] and p["ts"] + p["dur"]
               <= s["ts"] + s["dur"] + 1e-6
               for p in phases for s in steps)
    # request track: stage slices + instants + flow arrows for the rid
    req = [e for e in events if e.get("tid") == rid and e["pid"] != 1]
    assert {e["name"] for e in req if e["ph"] == "X"} >= {"prefill",
                                                          "decode"}
    assert any(e["ph"] == "i" and e["name"] == tr.SUBMIT for e in req)
    flows = [e for e in events if e["ph"] in ("s", "t", "f")]
    assert flows and all(e["id"] == rid for e in flows)


# ---------------------------------------------------------------------------
# engine.metrics() — the unified snapshot
# ---------------------------------------------------------------------------


def test_metrics_unified_snapshot_and_prefix_stats_view(rng):
    eng = _engine()
    eng.submit(_prompt(rng, 12), SamplingParams(max_tokens=6))
    eng.drain()
    m = eng.metrics()
    assert set(m) == {"engine", "scheduler", "prefix_cache", "block_pool",
                      "trace"}
    assert m["engine"]["phases"]["step.total_s"]["count"] > 0
    for phase in ("step.admit_s", "step.decode_dispatch_s",
                  "step.device_sync_s", "step.sample_host_s",
                  "step.prefix_match_s"):
        assert phase in m["engine"]["phases"], phase
    assert m["scheduler"]["finished"] == 1
    assert m["scheduler"]["queue_depth"] == 0
    assert m["block_pool"]["used_blocks"] >= 1
    assert 0 < m["block_pool"]["occupancy"] <= 1
    assert m["prefix_cache"]["prefill_tokens"] == 12
    # prefix_stats() is a view of the unified snapshot
    assert eng.prefix_stats() == m["prefix_cache"]


def test_reset_clears_metrics_and_trace(rng):
    """Back-to-back bench runs on one engine start from clean counters:
    a reset pass must report identical lifecycle counts to the first."""
    eng = _engine(prefill_chunk=BS)

    def run():
        for i in range(3):
            eng.submit(_prompt(rng, 5 + 4 * i), SamplingParams(max_tokens=4,
                                                               seed=i))
        eng.drain()
        m = eng.metrics()
        return {"steps": m["engine"]["counters"]["step.count"],
                "finished": m["scheduler"]["finished"],
                "submitted": m["scheduler"]["submitted"],
                "prefill_tokens": m["prefix_cache"]["prefill_tokens"],
                "events": m["trace"]["events"]}

    rng_state = rng.bit_generator.state
    first = run()
    assert first["finished"] == 3 and first["events"] > 0
    eng.reset()
    assert len(eng.tracer) == 0 and eng.tracer.dropped == 0
    m = eng.metrics()
    assert m["scheduler"]["submitted"] == 0
    assert m["engine"]["counters"].get("step.count", 0) == 0
    assert m["engine"]["phases"]["step.total_s"]["count"] == 0
    assert m["prefix_cache"]["prefill_tokens"] == 0
    assert m["prefix_cache"]["lookups"] == 0
    rng.bit_generator.state = rng_state  # same prompts second time
    assert run() == first


def test_disabled_observability_is_inert_and_token_exact(rng):
    prompts = [_prompt(rng, 7 + i) for i in range(3)]
    on, off = _engine(), _engine(enable_metrics=False)
    outs = []
    for eng in (on, off):
        rids = [eng.submit(p, SamplingParams(max_tokens=5, seed=i)) for i,
                p in enumerate(prompts)]
        out = eng.drain()
        outs.append([out[r] for r in rids])
    for a, b in zip(*outs):
        np.testing.assert_array_equal(a, b)
    assert len(off.tracer) == 0
    m = off.metrics()
    assert m["engine"]["phases"] == {} and m["engine"]["counters"] == {}
    # scheduler gauges and prefix stats still work (pure bookkeeping)
    assert m["scheduler"]["finished"] == 3
    assert m["prefix_cache"]["prefill_tokens"] > 0


# ---------------------------------------------------------------------------
# Scheduler gauges under pool starvation (engine-level regression)
# ---------------------------------------------------------------------------


def test_unadmit_under_pool_starvation_no_gauge_drift(rng):
    """Starve the BlockPool so admissions bounce via ``unadmit()`` for
    several steps: after every step the incremental scheduler gauges must
    equal a recount from the SlotStates, and the bounces must be visible
    as unadmit events/counters."""
    eng = _engine(n_slots=2, prefill_chunk=BS)
    pool = eng.prefix_cache.pool
    pinned = pool.alloc(pool.n_free())
    pool.incref(pinned)
    rids = [eng.submit(_prompt(rng, 10 + i),
                       SamplingParams(max_tokens=5, seed=i))
            for i in range(2)]
    for _ in range(3):
        eng.step()
        g = eng.scheduler.gauges()
        for k, v in eng.scheduler.recount().items():
            assert g[k] == v, f"gauge {k} drifted after starved step"
    g = eng.scheduler.gauges()
    assert g["unadmitted"] >= 2 and g["queue_depth"] == 2
    assert g["active_slots"] == 0 and g["prefilling_slots"] == 0
    unadmits = [e for e in eng.tracer.events() if e.kind == tr.UNADMIT]
    assert len(unadmits) == g["unadmitted"]
    assert all(e.fields["blocks_free"] == 0 for e in unadmits)

    pool.decref(pinned)
    pool.free(pinned)
    out = eng.drain()
    assert sorted(out) == sorted(rids)
    g = eng.scheduler.gauges()
    for k, v in eng.scheduler.recount().items():
        assert g[k] == v, f"gauge {k} drifted after drain"
    assert g["finished"] == 2 and g["free_slots"] == 2
