"""Paged-attention decode parity: the fused kernel (Pallas and the XLA
scan fallback) must match the einsum-over-gather reference bit-for-token —
op level against ``full_attention`` over the materialized gather, and
engine level (``use_paged_kernel=True``) against the default gather engine
on shared-prefix and chunked-prefill workloads, greedy and seeded
temperature. Also pins the dtype-aware mask value (finite in fp16) that
replaced the old ``-1e30`` constant."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from conftest import interpret_modes
from repro.kernels.paged_attention import mask_value, paged_attention_decode
from repro.models import params as pp
from repro.models.attention import full_attention
from repro.models.model import Model
from repro.serve import (ContinuousBatchingEngine, EngineConfig,
                         SamplingParams)

MAX_LEN = 48
BS = 8  # arena block size


# ---------------------------------------------------------------------------
# mask value (satellite bugfix: -1e30 overflows to -inf in fp16)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
def test_mask_value_finite_and_annihilating(dtype):
    m = mask_value(dtype)
    # finite in the target dtype (the old -1e30 became -inf in fp16, and
    # -inf - -inf = NaN poisons the softmax the moment a row is all-masked)
    assert np.isfinite(np.asarray(m, dtype))
    assert m < 0
    # still annihilates under softmax: exp(m - finite_max) == 0
    assert float(jnp.exp(jnp.asarray(m, jnp.float32))) == 0.0


def test_all_masked_row_is_nan_free():
    # a slot whose table is entirely trash blocks (freshly cleared slot)
    # produces an all-masked score row; the output must be finite
    q = jnp.ones((1, 1, 2, 8), jnp.float32)
    k = jnp.ones((3, BS, 2, 8), jnp.float32)
    pos = jnp.full((3, BS), -1, jnp.int32)
    tables = jnp.zeros((1, 2), jnp.int32)  # all trash
    out = paged_attention_decode(q, k, k, pos, tables, jnp.array([5]),
                                 impl="xla")
    assert np.all(np.isfinite(np.asarray(out)))


# ---------------------------------------------------------------------------
# op-level parity vs the materialized gather reference
# ---------------------------------------------------------------------------


def _make_arena(rng, *, b=3, nb=4, n_blocks=9, hkv=2, g=2, dh=16):
    """Random arena with the serve engine's invariants: block 0 is trash
    (garbage pos plane!), tables have trash-padded tails, the last live
    block of each row is partially filled."""
    h = hkv * g
    q = jnp.asarray(rng.normal(0, 1, (b, 1, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (n_blocks, BS, hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (n_blocks, BS, hkv, dh)), jnp.float32)
    pos = np.full((n_blocks, BS), -1, np.int32)
    # block 0 holds garbage positions from free-slot dummy decode writes;
    # the kernel must mask table entries == 0 wholesale, not trust pos
    pos[0] = rng.integers(0, 8, (BS,))
    tables = np.zeros((b, nb), np.int32)
    q_pos = np.zeros((b,), np.int32)
    free = list(range(1, n_blocks))
    for r in range(b):
        n_live = int(rng.integers(1, nb + 1))
        n_tok = (n_live - 1) * BS + int(rng.integers(1, BS + 1))
        for j in range(n_live):
            blk = free.pop()
            tables[r, j] = blk
            filled = min(BS, n_tok - j * BS)
            pos[blk, :filled] = np.arange(j * BS, j * BS + filled)
        q_pos[r] = n_tok - 1
    return q, k, v, jnp.asarray(pos), jnp.asarray(tables), \
        jnp.asarray(q_pos)


def _gather_reference(q, k, v, pos, tables, q_pos, *, causal, window):
    """The reference path from models/attention.py, verbatim semantics."""
    b, nb = tables.shape
    gk = k[tables].reshape((b, nb * BS) + k.shape[2:])
    gv = v[tables].reshape((b, nb * BS) + v.shape[2:])
    gp = jnp.where((tables == 0)[:, :, None], -1,
                   pos[tables]).reshape(b, nb * BS)
    return full_attention(q, gk, gv, q_pos=q_pos[:, None], kv_pos=gp,
                          causal=causal, window=window)


@pytest.mark.parametrize("causal,window", [(True, None), (True, 12),
                                           (False, None)])
@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
def test_op_parity_vs_gather(rng, impl, causal, window):
    q, k, v, pos, tables, q_pos = _make_arena(rng)
    want = np.asarray(_gather_reference(q, k, v, pos, tables, q_pos,
                                        causal=causal, window=window))
    got = np.asarray(paged_attention_decode(
        q, k, v, pos, tables, q_pos, causal=causal, window=window,
        impl=impl))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("interpret", interpret_modes())
def test_pallas_modes_match_xla(rng, interpret):
    """Kernel parity in both interpret modes (compiled runs on TPU/GPU
    runners, interpret everywhere): the Pallas kernel and the scan
    fallback share one accumulation contract."""
    q, k, v, pos, tables, q_pos = _make_arena(rng, b=2, nb=3, n_blocks=7)
    want = np.asarray(paged_attention_decode(
        q, k, v, pos, tables, q_pos, impl="xla"))
    impl = "pallas_interpret" if interpret else "pallas"
    got = np.asarray(paged_attention_decode(
        q, k, v, pos, tables, q_pos, impl=impl))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_fp16_cache_stays_finite(rng):
    q, k, v, pos, tables, q_pos = _make_arena(rng, b=2, nb=3, n_blocks=7)
    out = paged_attention_decode(
        q.astype(jnp.float16), k.astype(jnp.float16), v.astype(jnp.float16),
        pos, tables, q_pos, impl="xla")
    assert out.dtype == jnp.float16
    assert np.all(np.isfinite(np.asarray(out, np.float32)))


def test_unknown_impl_rejected(rng):
    q, k, v, pos, tables, q_pos = _make_arena(rng, b=1, nb=2, n_blocks=5)
    with pytest.raises(ValueError, match="impl"):
        paged_attention_decode(q, k, v, pos, tables, q_pos, impl="cuda")


# ---------------------------------------------------------------------------
# engine-level parity: fused decode vs the gather engine, token-exact
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=1)
def _setup():
    cfg = C.get_smoke("smollm-135m").replace(compute_dtype="float32")
    params = pp.init_params(Model(cfg).build(), jax.random.key(0))
    return cfg, params


def _shared_prefix_prompts(rng, n, sys_len=2 * BS + 1):
    cfg, _ = _setup()
    sys_p = rng.integers(0, cfg.vocab, (sys_len,)).astype(np.int32)
    return [np.concatenate([sys_p,
                            rng.integers(0, cfg.vocab,
                                         (3 + i % 5,)).astype(np.int32)])
            for i in range(n)]


def _run(prompts, n_tok, temperature, *, paged, **kw):
    cfg, params = _setup()
    eng = ContinuousBatchingEngine(cfg, params,
                                   config=EngineConfig(max_len=MAX_LEN,
                                                       n_slots=3,
            block_size=BS, use_paged_kernel=paged is not None,
            paged_impl=paged, **kw))
    rids = [eng.submit(p, SamplingParams(max_tokens=n_tok,
                                         temperature=temperature, seed=i))
            for i, p in enumerate(prompts)]
    out = eng.drain()
    return [out[r] for r in rids]


@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_engine_shared_prefix_token_exact(rng, temperature):
    prompts = _shared_prefix_prompts(rng, 6)
    want = _run(prompts, 8, temperature, paged=None)
    got = _run(prompts, 8, temperature, paged="xla")
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)


@pytest.mark.parametrize("temperature", [0.0, 0.7])
def test_engine_chunked_prefill_token_exact(rng, temperature):
    prompts = _shared_prefix_prompts(rng, 5, sys_len=3 * BS + 2)
    want = _run(prompts, 6, temperature, paged=None, prefill_chunk=BS)
    got = _run(prompts, 6, temperature, paged="xla", prefill_chunk=BS)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)


def test_engine_pallas_interpret_token_exact(rng):
    # one small run through the actual kernel body (interpreted): the
    # engine wiring for impl="pallas" differs from "xla" only in dispatch
    prompts = _shared_prefix_prompts(rng, 2)[:2]
    want = _run(prompts, 3, 0.0, paged=None)
    got = _run(prompts, 3, 0.0, paged="pallas_interpret")
    for g, w in zip(got, want):
        np.testing.assert_array_equal(g, w)


def test_paged_requires_block_mode(rng):
    cfg, params = _setup()
    with pytest.raises(ValueError, match="block-mode"):
        ContinuousBatchingEngine(cfg, params,
                                 config=EngineConfig(max_len=MAX_LEN,
                                                     n_slots=2,
                prefix_cache=False, use_paged_kernel=True))
