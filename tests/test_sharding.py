"""Sharding rules + roofline parsing (no multi-device mesh needed)."""
import jax
from jax.sharding import PartitionSpec as PS

import repro.configs as C
from repro.launch import roofline as RL
from repro.models.model import Model
from repro.models.params import P


class FakeMesh:
    """Duck-typed mesh exposing .shape for Rules' divisibility logic."""

    def __init__(self, **axes):
        self.shape = dict(axes)


def _rules(**axes):
    from repro.parallel.sharding import Rules

    return Rules(mesh=FakeMesh(**axes), mapping=None or dict(
        __import__("repro.parallel.sharding", fromlist=["DEFAULT_MAPPING"]
                   ).DEFAULT_MAPPING))


def test_divisibility_fallback():
    r = _rules(data=16, model=16)
    # kv_proj = 8 heads * 128: divisible => sharded
    assert r.spec_for(("embed", "kv_proj"), (12288, 1024)) == PS(None, "model")
    # 9 attention heads on a 16-way axis: dropped
    assert r.spec_for(("batch", "heads", None), (256, 9, 64))[1] is None
    # batch 256 over ('pod','data') when no pod axis: falls back to data
    assert r.spec_for(("batch", None), (256, 4096)) == PS("data", None)


def test_multipod_batch_sharding():
    r = _rules(pod=2, data=16, model=16)
    assert r.spec_for(("batch", None), (256, 10))[0] == ("pod", "data")
    # batch=1 (long_500k): everything dropped
    assert r.spec_for(("batch", None), (1, 10)) == PS(None, None)


def test_no_axis_reuse_within_spec():
    r = _rules(data=2, model=4)
    # expert and mlp both map to model: only the first gets it
    spec = r.spec_for(("expert", "embed", "mlp"), (8, 64, 64))
    used = [s for s in spec if s is not None]
    assert used.count("model") <= 1


def test_fsdp_spec_adds_data_axis():
    r = _rules(data=16, model=16)
    tree = {"w": P((1024, 512), ("embed", "mlp"))}
    plain = r.param_specs(tree)["w"]
    fsdp = r.param_specs(tree, fsdp=True)["w"]
    assert plain == PS(None, "model")
    assert fsdp == PS("data", "model")


def test_param_and_spec_trees_congruent():
    r = _rules(data=16, model=16)
    for arch in C.ARCH_IDS:
        tree = Model(C.get_config(arch)).build()
        specs = r.param_specs(tree)
        assert jax.tree_util.tree_structure(
            jax.tree.map(lambda x: 0, tree,
                         is_leaf=lambda x: isinstance(x, P))) == \
            jax.tree_util.tree_structure(
                jax.tree.map(lambda x: 0, specs,
                             is_leaf=lambda s: isinstance(s, PS)))


HLO_SAMPLE = """
  %all-reduce.1 = f32[1024,512]{1,0} all-reduce(%dot.1), channel_id=1, replica_groups={{0,1,2,3},{4,5,6,7}}, use_global_device_ids=true, to_apply=%add
  %all-gather.2 = bf16[64,2048]{1,0} all-gather(%p0), channel_id=2, replica_groups=[32,16]<=[512], dimensions={0}
  %rs = f32[16,16]{1,0} reduce-scatter(%x), channel_id=3, replica_groups={{0,1}}, to_apply=%add
  %cp = f32[8,8]{1,0} collective-permute(%y), channel_id=4, source_target_pairs={{0,1}}
"""


def test_collective_parser():
    out = RL.collective_bytes(HLO_SAMPLE)
    ar = 1024 * 512 * 4
    assert abs(out["all-reduce"] - 2 * ar * 3 / 4) < 1
    ag = 64 * 2048 * 2
    assert abs(out["all-gather"] - ag * 15 / 16) < 1
    rs = 16 * 16 * 4
    assert abs(out["reduce-scatter"] - rs * 1) < 1
    assert out["collective-permute"] == 8 * 8 * 4
    assert out["counts"]["all-reduce"] == 1


def test_roofline_terms_bottleneck():
    t = RL.roofline_terms(197e12, 819e9 * 2, 50e9 * 0.5)
    assert t["bottleneck"] == "memory"
    assert abs(t["compute_s"] - 1.0) < 1e-9
    assert abs(t["memory_s"] - 2.0) < 1e-9
    t2 = RL.roofline_terms(197e12 * 3, 819e9, 50e9)
    assert t2["bottleneck"] == "compute"
