"""Serve a small model with SWIS-compressed (bit-plane packed) weights and
batched requests: prefill + greedy decode through the ring KV cache.

Run:  PYTHONPATH=src python examples/serve_swis.py [--batch 4 --tokens 16]
"""
import argparse

import jax
import numpy as np

import repro.configs as C
from repro.core.swis import QuantConfig
from repro.models import params as pp
from repro.models.model import Model
from repro.serve import DecodeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--n-shifts", type=int, default=4)
    args = ap.parse_args()

    cfg = C.get_smoke(args.arch).replace(compute_dtype="float32")
    params = pp.init_params(Model(cfg).build(), jax.random.key(0))

    qcfg = QuantConfig(method="swis", n_shifts=args.n_shifts, group_size=4)
    dense = DecodeEngine(cfg, params, max_len=64, batch=args.batch)
    packed = DecodeEngine(cfg, params, max_len=64, batch=args.batch,
                          packed=True, quant_cfg=qcfg)
    print(f"packed {packed.pack_stats['n_packed']} GEMM weights, "
          f"compression {packed.pack_stats['compression']:.2f}x "
          f"(N={args.n_shifts} shifts, group 4)")

    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab, (args.batch, 8)).astype(np.int32)
    out_d = dense.generate(prompt, args.tokens)
    out_p = packed.generate(prompt, args.tokens)
    agree = float((out_d == out_p).mean())
    print(f"generated {args.tokens} tokens x {args.batch} requests; "
          f"dense-vs-packed token agreement: {agree:.2f}")
    print("packed sample:", out_p[0].tolist())


if __name__ == "__main__":
    main()
