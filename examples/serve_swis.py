"""Serve a small model with SWIS-compressed (bit-plane packed) weights
through the continuous-batching engine: requests with different prompt
lengths and token budgets join mid-flight, prefilling into free slots while
earlier requests keep decoding.

Run:  PYTHONPATH=src python examples/serve_swis.py [--n-slots 2 --tokens 16]
"""
import argparse

import jax
import numpy as np

import repro.configs as C
from repro.core.swis import QuantConfig
from repro.models import params as pp
from repro.models.model import Model
from repro.serve import (ContinuousBatchingEngine, DecodeEngine,
                         EngineConfig, SamplingParams)
from repro.serve.metrics import format_report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--n-slots", type=int, default=2)
    ap.add_argument("--requests", type=int, default=5)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--n-shifts", type=int, default=4)
    args = ap.parse_args()

    cfg = C.get_smoke(args.arch).replace(compute_dtype="float32")
    params = pp.init_params(Model(cfg).build(), jax.random.key(0))

    qcfg = QuantConfig(method="swis", n_shifts=args.n_shifts, group_size=4)
    eng = ContinuousBatchingEngine(cfg, params, config=EngineConfig(
        max_len=64, n_slots=args.n_slots, packed=True, quant_cfg=qcfg))
    print(f"packed {eng.pack_stats['n_packed']} GEMM weights, "
          f"compression {eng.pack_stats['compression']:.2f}x "
          f"(N={args.n_shifts} shifts, group 4); "
          f"{args.n_slots} decode slots")

    # mixed prompt lengths, staggered arrival: half the requests are
    # submitted only after the engine has already been decoding for a while
    rng = np.random.default_rng(0)
    lens = rng.integers(4, 17, args.requests)
    prompts = [rng.integers(0, cfg.vocab, (l,)).astype(np.int32)
               for l in lens]
    results = {}

    def collect(finished):
        for f in finished:
            results[f.rid] = np.concatenate([f.prompt, f.tokens])

    rids = [eng.submit(p, SamplingParams(max_tokens=args.tokens, seed=i))
            for i, (p) in enumerate(prompts[: len(prompts) // 2 + 1])]
    for _ in range(4):  # decode a few steps before the late arrivals
        collect(eng.step())
    rids += [eng.submit(p, SamplingParams(max_tokens=args.tokens,
                                          seed=len(rids) + i))
             for i, p in enumerate(prompts[len(prompts) // 2 + 1:])]
    results.update(eng.drain())

    # parity spot-check: each request must match its solo static-batch run
    legacy = DecodeEngine(cfg, params, max_len=64, batch=1, packed=True,
                          quant_cfg=qcfg)
    legacy_ok = 0
    for p, rid in zip(prompts, rids):
        want = legacy.generate(p[None], args.tokens)[0]
        legacy_ok += int(np.array_equal(results[rid][len(p):],
                                        want[len(p):]))
    print(f"served {len(rids)} mixed-length requests "
          f"({lens.min()}-{lens.max()} prompt tokens) x {args.tokens} "
          f"generated; {legacy_ok}/{len(rids)} match the static-batch "
          f"engine token-for-token")
    print("sample:", results[rids[0]].tolist())

    # the observability layer every serve-path change is judged against:
    # one unified snapshot — cache health, arena occupancy, scheduler
    # counters, per-phase step latency (docs/serving.md "Observability")
    m = eng.metrics()
    if "block_pool" in m:
        print(f"prefix cache: hit_rate="
              f"{m['prefix_cache']['hit_rate']:.2f} "
              f"saved_tokens={m['prefix_cache']['saved_tokens']} "
              f"pool_occupancy={m['block_pool']['occupancy']:.2f} "
              f"({m['block_pool']['used_blocks']}/"
              f"{m['block_pool']['usable_blocks']} blocks)")
    print(f"scheduler: finished={m['scheduler']['finished']} "
          f"admitted={m['scheduler']['admitted']} "
          f"unadmitted={m['scheduler']['unadmitted']}")
    snap = eng.metrics_registry.snapshot()
    print(format_report(snap, title="step-phase timing + dispatch costs"))
    # analytical per-dispatch cost model: predicted HBM traffic of the
    # packed weights vs what 8-bit dense would have streamed
    cm = m["engine"]["cost_model"]
    print(f"cost model: {cm['n_packed_leaves']}/{cm['n_gemm_leaves']} "
          f"GEMMs packed, {cm['weight_bytes_per_dispatch'] / 2**20:.2f}"
          f"MiB weight traffic/dispatch "
          f"(8-bit dense: {cm['weight_bytes_dense8'] / 2**20:.2f}MiB); "
          f"predicted total "
          f"{snap['counters'].get('cost.hbm_bytes', 0) / 2**20:.1f}MiB "
          f"moved at "
          f"{snap['gauges'].get('cost.hbm_bytes_per_s', 0) / 2**20:.1f}"
          f"MiB/s model-implied bandwidth")
    tsum = eng.tracer.summary()
    if tsum["ttft_s"]:
        print(f"ttft: p50={tsum['ttft_s']['p50'] * 1e3:.1f}ms "
              f"p95={tsum['ttft_s']['p95'] * 1e3:.1f}ms  "
              f"tpot: p50={tsum['tpot_s']['p50'] * 1e3:.2f}ms "
              f"(from {tsum['events']} trace events)")


if __name__ == "__main__":
    main()
