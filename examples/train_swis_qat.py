"""End-to-end driver: train a ~135M-class LM (smollm-135m family) with SWIS
quantization-aware training for a few hundred steps, with checkpointing,
then evaluate PTQ-vs-QAT accuracy at the deployment shift count.

The default uses a width/depth-reduced smollm so a few hundred steps finish
on CPU; pass --full to instantiate the exact 135M config (slow on CPU, the
real target is the TPU mesh via repro.launch.train).

Run:  PYTHONPATH=src python examples/train_swis_qat.py [--steps 300]
"""
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import argparse
import dataclasses
import os

import repro.configs as C
from repro.configs.base import QuantPolicy
from repro.core.swis import QuantConfig
from repro.train.loop import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--n-shifts", type=float, default=2)
    ap.add_argument("--workdir", default="results/example_qat")
    ap.add_argument("--full", action="store_true",
                    help="use the exact smollm-135m config")
    args = ap.parse_args()

    cfg = C.get_config("smollm-135m") if args.full else C.get_smoke(
        "smollm-135m")
    qcfg = QuantConfig(method="swis", n_shifts=args.n_shifts, group_size=4)
    cfg_qat = cfg.replace(quant=QuantPolicy(cfg=qcfg, mode="qat"))

    print(f"== SWIS QAT: {cfg.name}, N={args.n_shifts} shifts, "
          f"{args.steps} steps ==")
    tr = Trainer(cfg_qat, seq_len=64, global_batch=16, workdir=args.workdir,
                 total_steps=args.steps, ckpt_every=max(args.steps // 4, 1),
                 warmup=20, peak_lr=3e-3)
    out = tr.run(args.steps)
    print(f"loss: {out['first_loss']:.3f} -> {out['last_loss']:.3f}")

    # eval: QAT weights under PTQ-style deployment quantization
    from benchmarks.common import quant_policy, trained_smoke_model

    if not args.full:
        base_cfg, base_params, eval_acc = trained_smoke_model(
            steps=args.steps)
        ptq_cfg = base_cfg.replace(quant=quant_policy("swis", args.n_shifts))
        acc_ptq = eval_acc(ptq_cfg)  # fp32-trained, then quantized
        acc_qat = eval_acc(ptq_cfg, eval_params=out["state"].params)
        print(f"accuracy @ N={args.n_shifts}:  PTQ={acc_ptq:.4f}  "
              f"QAT={acc_qat:.4f}  (QAT recovers accuracy, paper Table 5)")


if __name__ == "__main__":
    main()
