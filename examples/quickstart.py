"""Quickstart: SWIS post-training quantization in 5 minutes.

1. Quantize a weight matrix with SWIS / SWIS-C / truncation and compare RMSE
   (paper Table 1).
2. Pack to the compressed bit-plane format and run the dequant-in-kernel
   matmul (Pallas interpret mode) against the dense result.
3. Quantize a whole model (PTQ) and compare task accuracy vs truncation.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing
from repro.core.swis import QuantConfig, fake_quant, quantize, rmse
from repro.kernels import ops


def main():
    rng = np.random.default_rng(0)

    print("== 1. SWIS vs SWIS-C vs truncation (RMSE, group=4) ==")
    w = jnp.asarray(rng.normal(0, 0.05, (256, 128)).astype(np.float32))
    for n in (2, 3, 4):
        row = []
        for method in ("swis", "swis_c", "trunc"):
            q = fake_quant(w, QuantConfig(method=method, n_shifts=n,
                                          group_size=4))
            row.append(f"{method}={float(rmse(w, q)):.5f}")
        print(f"  N={n}: " + "  ".join(row))

    print("\n== 2. Packed bit-plane matmul (the TPU serving path) ==")
    qcfg = QuantConfig(method="swis", n_shifts=3, group_size=4)
    qw = quantize(w, qcfg)
    pw = packing.pack(qw)
    print(f"  compression: {pw.compression_ratio:.2f}x vs int8 "
          f"({pw.stored_bits / 8 / 1024:.1f} KiB packed)")
    x = jnp.asarray(rng.normal(0, 1, (16, 256)).astype(np.float32))
    y_packed = ops.swis_matmul(x, pw, use_pallas=True, interpret=True)
    y_dense = x @ qw.qweights
    err = float(jnp.max(jnp.abs(y_packed - y_dense))
                / jnp.max(jnp.abs(y_dense)))
    print(f"  pallas-vs-dense rel err: {err:.2e}")

    print("\n== 3. Whole-model PTQ on a small LM ==")
    from benchmarks.common import quant_policy, trained_smoke_model

    cfg, params, eval_acc = trained_smoke_model(steps=200)
    print(f"  fp32 accuracy:        {eval_acc(cfg):.4f}")
    for n in (2, 3, 4):
        a_swis = eval_acc(cfg.replace(quant=quant_policy("swis", n)))
        a_tr = eval_acc(cfg.replace(quant=quant_policy("trunc", n)))
        print(f"  N={n}: swis={a_swis:.4f}  wgt-trunc={a_tr:.4f}")


if __name__ == "__main__":
    main()
