"""Pallas TPU kernel: SWIS dequant-in-kernel matmul.

TPU-native realization of the paper's bit-serial PE (DESIGN.md §2): the
compressed SWIS representation (sign bit-plane + N mask bit-planes packed in
uint32 lanes + per-group 3-bit shifts) streams HBM->VMEM, the kernel
reconstructs an integer weight tile *in VMEM* (the analogue of the shift-
accumulate loop, Eq. 7) and feeds the MXU with a dense tile:

    w_tile[k, n] = sign[k, n] * sum_j  mask_j[k, n] << shifts[k // M, n, j]
    out[i, n]   += x[i, k] @ (w_tile * scale[n])

The HBM weight traffic is the *packed* bytes — (M(1+N)+3N)/(8M) of the int8
baseline — which is where SWIS's win lands on TPU (memory roofline term).

Tiling: grid (M_rows/bm, N_cols/bn, K/bk); the fp32 accumulator lives in the
output VMEM block across the K loop (output-stationary, like the paper's OS
systolic dataflow). bk must be a multiple of 32 (bit packing) and of the
group size M; bn a multiple of 128 (lane width); bm a multiple of 8.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl



def _swis_matmul_kernel(
    x_ref,  # (bm, bk) activation tile
    sign_ref,  # (bk // 32, bn) uint32
    mask_ref,  # (n_shifts, bk // 32, bn) uint32
    shift_ref,  # (bk // group, bn, ceil(n_shifts/2)) uint8 nibble-packed
    scale_ref,  # (1, bn) float32
    o_ref,  # (bm, bn) float32 accumulator
    *,
    n_shifts: int,
    group: int,
    bk: int,
    k_steps: int,
    consecutive: bool,
    keep_slices=None,
):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    words = bk // 32
    bn = sign_ref.shape[-1]
    lane = jax.lax.broadcasted_iota(jnp.uint32, (words, 32, bn), 1)

    # Sign plane: bit=1 -> negative.
    sbits = (sign_ref[...][:, None, :] >> lane) & jnp.uint32(1)
    sign = (1 - 2 * sbits.astype(jnp.int32)).reshape(bk, bn)

    # Shift-accumulate (Eq. 7): one mask plane per shift index. The plane
    # loop is unrolled (n_shifts is static) — the double-shift PE of §3.1
    # corresponds to the compiler pipelining two planes per pass.
    # keep_slices truncates execution to the top-k most significant planes
    # (shift combos are ascending, so plane n_shifts-1 carries the largest
    # shift): the bit-serial PE simply stops k cycles early, which is the
    # truncated-precision draft execution speculative decode runs on.
    first = 0 if keep_slices is None else n_shifts - keep_slices
    w_mag = jnp.zeros((bk, bn), jnp.int32)
    for j in range(first, n_shifts):
        mbits = (mask_ref[j][:, None, :] >> lane) & jnp.uint32(1)
        mbits = mbits.astype(jnp.int32).reshape(bk, bn)
        if consecutive:  # SWIS-C: shift j = per-group offset + j
            s = shift_ref[:, :, 0].astype(jnp.int32) + j
        else:
            byte = shift_ref[:, :, j // 2].astype(jnp.int32)
            s = (byte >> (4 * (j % 2))) & 0xF  # (bk // group, bn)
        s_full = jnp.broadcast_to(
            s[:, None, :], (bk // group, group, bn)
        ).reshape(bk, bn)
        w_mag = w_mag + (mbits << s_full)

    w = (sign * w_mag).astype(x_ref.dtype)
    acc = jax.lax.dot_general(
        x_ref[...],
        w,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[...] += acc

    @pl.when(k_idx == k_steps - 1)
    def _finish():
        o_ref[...] *= scale_ref[0, :][None, :]


@functools.partial(
    jax.jit,
    static_argnames=("n_shifts", "group", "bm", "bn", "bk", "interpret",
                     "consecutive", "keep_slices"),
)
def swis_matmul_packed(
    x: jnp.ndarray,
    sign_plane: jnp.ndarray,
    mask_planes: jnp.ndarray,
    shifts: jnp.ndarray,
    scale: jnp.ndarray,
    *,
    n_shifts: int,
    group: int,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    interpret: bool = True,
    consecutive: bool = False,
    keep_slices=None,
):
    """``x (M, K) @ dequant(packed (K, N)) -> (M, N) float32``.

    See module docstring for the packed layout. ``interpret=True`` executes
    the kernel body in Python on CPU (validation); on real TPU pass False.
    ``keep_slices=k`` evaluates only the k most significant bit-planes —
    the truncated-precision execution that a bit-serial PE gets by ending
    its shift-accumulate loop early (speculative-draft path).
    """
    if keep_slices is not None and not 1 <= keep_slices <= n_shifts:
        raise ValueError(
            f"keep_slices must be in [1, {n_shifts}], got {keep_slices}")
    m, k = x.shape
    kw, n = sign_plane.shape
    assert kw * 32 == k, (kw, k)
    bm = min(bm, m)
    bn = min(bn, n)
    bk = min(bk, k)
    if m % bm or n % bn or k % bk:
        raise ValueError(f"shape ({m},{k})x({k},{n}) not divisible by tiles "
                         f"({bm},{bn},{bk})")
    if bk % 32 or bk % group:
        raise ValueError(f"bk={bk} must be a multiple of 32 and group={group}")
    k_steps = k // bk
    grid = (m // bm, n // bn, k_steps)

    kernel = functools.partial(
        _swis_matmul_kernel,
        n_shifts=n_shifts,
        group=group,
        bk=bk,
        k_steps=k_steps,
        consecutive=consecutive,
        keep_slices=keep_slices,
    )
    scale2d = jnp.broadcast_to(jnp.asarray(scale, jnp.float32).reshape(1, -1), (1, n))

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk // 32, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((n_shifts, bk // 32, bn), lambda i, j, kk: (0, kk, j)),
            pl.BlockSpec((bk // group, bn,
                          1 if consecutive else (n_shifts + 1) // 2),
                         lambda i, j, kk: (kk, j, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, sign_plane, mask_planes, shifts, scale2d)
