"""Jit'd public wrappers around the SWIS Pallas kernels.

``swis_matmul`` dispatches between the Pallas kernel (TPU target /
interpret-mode validation) and the pure-jnp reference path (CPU + dry-run:
identical math and identical *packed* HBM operands, so cost_analysis sees the
compressed weight bytes either way).

A custom VJP makes the packed matmul differentiable w.r.t. the activations
(weights are frozen post-PTQ), so packed serving graphs can still be
jacobian-tested.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.packing import PackedWeight
from repro.kernels import ref as _ref
from repro.kernels.swis_matmul import swis_matmul_packed


def _pick_tiles(m: int, k: int, n: int, group: int):
    def largest(div, cands):
        for c in cands:
            if div % c == 0:
                return c
        return div

    bm = largest(m, (128, 64, 32, 16, 8, 4, 2, 1))
    bn = largest(n, (128, 256, 64, 32))
    bk_base = 512
    while bk_base > 32 and (k % bk_base or bk_base % group):
        bk_base //= 2
    bk = bk_base if (k % bk_base == 0 and bk_base % group == 0) else k
    return bm, bn, bk


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _matmul(x, planes, static):
    group, n_shifts, use_pallas, interpret, consecutive, keep_slices = static
    sign_plane, mask_planes, shifts, scale = planes
    if use_pallas:
        m, k = x.shape
        n = sign_plane.shape[1]
        bm, bn, bk = _pick_tiles(m, k, n, group)
        return swis_matmul_packed(
            x, sign_plane, mask_planes, shifts, scale,
            n_shifts=n_shifts, group=group, bm=bm, bn=bn, bk=bk,
            interpret=interpret, consecutive=consecutive,
            keep_slices=keep_slices,
        )
    return _ref.swis_matmul_ref(
        x, sign_plane, mask_planes, shifts, scale, group=group,
        consecutive=consecutive, keep_slices=keep_slices,
    )


def _matmul_fwd(x, planes, static):
    return _matmul(x, planes, static), planes


def _matmul_bwd(static, planes, g):
    group, consecutive, keep_slices = static[0], static[4], static[5]
    sign_plane, mask_planes, shifts, scale = planes
    # the gradient of a truncated matmul w.r.t. x is the truncated w^T:
    # keep_slices flows into the bwd dequant so jacobian tests stay exact
    w = _ref.dequant_ref(sign_plane, mask_planes, shifts, scale, group=group,
                         dtype=g.dtype, consecutive=consecutive,
                         keep_slices=keep_slices)
    return (g @ w.T, None)


_matmul.defvjp(_matmul_fwd, _matmul_bwd)


def swis_matmul(
    x: jnp.ndarray,
    pw: PackedWeight,
    *,
    use_pallas: bool = False,
    interpret: bool = True,
    keep_slices=None,
) -> jnp.ndarray:
    """``x @ dequant(pw)`` for arbitrary-rank ``x`` (matmul over last axis).

    ``keep_slices=k`` evaluates only the k most significant bit-planes
    (truncated-precision execution; None = all planes)."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    static = (pw.group_size, pw.n_shifts, use_pallas, interpret,
              pw.method == "swis_c", keep_slices)
    planes = (pw.sign_plane, pw.mask_planes, pw.shifts, pw.scale)
    y = _matmul(x2, planes, static)
    return y.reshape(*shape[:-1], y.shape[-1])
