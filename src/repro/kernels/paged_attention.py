"""Pallas TPU kernel: paged GQA attention over a block arena.

Decode (and mixed chunk+decode) attention against the serve engine's
physical-block KV arena (``repro.serve.kv_cache.SlotKVCache`` block mode)
*without* materializing the gathered K/V. The gather path
(``models/attention.py``) re-builds an O(B * n_logical_blocks * block_size
* Hkv * Dh) contiguous view of every slot's cache each step — exactly the
copy paged attention exists to avoid. Here the grid iterates (slot,
kv-head, logical block); each program reads ``block_tables[slot, j]`` from
SMEM (scalar prefetch, so the index is known before the body runs) and
DMAs only that physical K/V block into VMEM. The softmax is accumulated
online across the block axis (flash-decoding style): running max /
denominator / weighted-V scratch persists across the innermost grid
dimension and the output block is finalized on the last logical block.

Rows may carry more than one query token (the fused mixed step batches
one-token decode rows together with an S-token prefill chunk row): row
``b``'s queries sit at absolute positions ``q_pos[b] + [0, S)`` and only
the first ``q_lens[b]`` of them are real — ``q_lens`` rides in as a
scalar-prefetch operand so decode rows (``q_lens == 1``) and chunk rows
(``q_lens == n_valid``) coexist in one grid, and queries past a row's
count are masked wholesale (their output rows finalize to zero).

Masking contract (identical to the gather path):
  * entries with ``pos < 0`` are invalid (unwritten / scrubbed / padding);
  * logical blocks mapped to the reserved trash block 0 are invalid
    wholesale, whatever garbage block 0's pos plane holds;
  * causal: ``pos <= q_pos[slot] + i`` per query ``i``; window:
    ``pos > q_pos[slot] + i - window``;
  * queries ``i >= q_lens[slot]`` are invalid (mixed-batch padding).

Two implementations behind one wrapper, both bit-identical in masking and
accumulation order:

  * ``impl="pallas"`` — the kernel above (``interpret=True`` runs the body
    in Python for CPU validation, same contract as ``swis_matmul_packed``);
  * ``impl="xla"`` — a ``lax.scan`` over logical blocks gathering one
    (B, block_size) K/V slab per step. Working set is O(B * block_size),
    never O(B * n_blocks * block_size); this is the serving path on
    backends without Pallas compile support.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # pltpu is importable on CPU builds; guard anyway for slim installs
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover - exercised only on partial installs
    pltpu = None


def mask_value(dtype) -> float:
    """Additive-mask fill for invalid attention scores: large-magnitude
    negative but comfortably inside ``dtype``'s range, so downcasting the
    scores (fp16/bf16/fp8 caches) never overflows to ``-inf`` (whose
    ``exp`` is a well-defined 0 but whose arithmetic breeds NaNs the
    moment two masked scores are subtracted)."""
    return float(jnp.finfo(jnp.dtype(dtype)).min) / 2


def _paged_decode_kernel(
    tables_ref,  # (B, nb) int32, SMEM scalar prefetch
    qpos_ref,  # (B,) int32, SMEM scalar prefetch: row's first query pos
    qlens_ref,  # (B,) int32, SMEM scalar prefetch: valid queries per row
    q_ref,  # (1, 1, Sq*G, Dh) this slot+kv-head's queries, Sq-major
    k_ref,  # (1, bs, 1, Dh) the *physical* block tables[b, j] points at
    v_ref,  # (1, bs, 1, Dh)
    pos_ref,  # (1, bs) int32 position plane of that physical block
    o_ref,  # (1, 1, Sq*G, Dh) output, revisited across the block axis
    m_ref,  # (Sq*G, 1) f32 scratch: running max
    l_ref,  # (Sq*G, 1) f32 scratch: running denominator
    acc_ref,  # (Sq*G, Dh) f32 scratch: running weighted V
    *,
    nb: int,
    sq: int,
    causal: bool,
    window: Optional[int],
):
    b = pl.program_id(0)
    j = pl.program_id(2)
    neg = mask_value(jnp.float32)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, neg)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    dh = q_ref.shape[-1]
    sg = q_ref.shape[-2]
    g = sg // sq
    bs = k_ref.shape[1]
    q = q_ref[0, 0].astype(jnp.float32) * (dh ** -0.5)  # (Sq*G, Dh)
    k = k_ref[0, :, 0].astype(jnp.float32)  # (bs, Dh)
    v = v_ref[0, :, 0].astype(jnp.float32)
    s = jax.lax.dot_general(  # (Sq*G, bs)
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    pos = pos_ref[0]  # (bs,)
    qp = qpos_ref[b]
    ql = qlens_ref[b]
    # per-score query index: score row i*G+g' belongs to query i, whose
    # absolute position is qp + i (TPU needs >= 2-D iota)
    qi = jax.lax.broadcasted_iota(jnp.int32, (sq, g, bs), 0)
    valid = jnp.broadcast_to(pos[None, None, :] >= 0, (sq, g, bs))
    # logical blocks parked on the trash block are invalid by definition
    valid &= tables_ref[b, j] != 0
    # queries past the row's count are padding: mask them wholesale so
    # their output rows finalize to exact zeros
    valid &= qi < ql
    if causal:
        valid &= pos[None, None, :] <= qp + qi
    if window is not None:
        valid &= pos[None, None, :] > qp + qi - window
    s = jnp.where(valid.reshape(sg, bs), s, neg)

    m_prev = m_ref[...]  # (Sq*G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)  # (Sq*G, bs)
    m_ref[...] = m_new
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(j == nb - 1)
    def _finish():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("sq", "causal", "window", "interpret"))
def _paged_attention_pallas(q4, k_arena, v_arena, pos_arena, block_tables,
                            q_pos, q_lens, *, sq, causal, window, interpret):
    """q4: (B, Hkv, Sq*G, Dh) -> (B, Hkv, Sq*G, Dh) float32."""
    b, hkv, sg, dh = q4.shape
    bs = k_arena.shape[1]
    nb = block_tables.shape[1]
    if pltpu is None:  # pragma: no cover
        raise RuntimeError("pallas TPU frontend unavailable")
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, hkv, nb),
        in_specs=[
            pl.BlockSpec((1, 1, sg, dh),
                         lambda bi, h, j, t, qp, ql: (bi, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, dh),
                         lambda bi, h, j, t, qp, ql: (t[bi, j], 0, h, 0)),
            pl.BlockSpec((1, bs, 1, dh),
                         lambda bi, h, j, t, qp, ql: (t[bi, j], 0, h, 0)),
            pl.BlockSpec((1, bs), lambda bi, h, j, t, qp, ql: (t[bi, j], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, sg, dh),
                               lambda bi, h, j, t, qp, ql: (bi, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((sg, 1), jnp.float32),
            pltpu.VMEM((sg, 1), jnp.float32),
            pltpu.VMEM((sg, dh), jnp.float32),
        ],
    )
    kernel = functools.partial(_paged_decode_kernel, nb=nb, sq=sq,
                               causal=causal, window=window)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, sg, dh), jnp.float32),
        interpret=interpret,
    )(jnp.asarray(block_tables, jnp.int32), jnp.asarray(q_pos, jnp.int32),
      jnp.asarray(q_lens, jnp.int32), q4, k_arena, v_arena, pos_arena)


def _paged_attention_xla(q4, k_arena, v_arena, pos_arena, block_tables,
                         q_pos, q_lens, *, sq, causal, window):
    """lax.scan over logical blocks: same masking and online-softmax
    accumulation as the kernel, one (B, block_size) gathered slab per step
    — the full gathered K/V is never materialized."""
    b, hkv, sg, dh = q4.shape
    g = sg // sq
    neg = mask_value(jnp.float32)
    qh = q4.astype(jnp.float32) * (dh ** -0.5)  # (B, Hkv, Sq*G, Dh)
    qi = jnp.arange(sq, dtype=jnp.int32)  # query index within the row
    # per-query absolute positions / validity, Sq-major like the q layout
    qpos = (q_pos[:, None] + qi[None, :])  # (B, Sq)
    qvalid = qi[None, :] < q_lens[:, None]  # (B, Sq)
    qpos_sg = jnp.repeat(qpos, g, axis=1)  # (B, Sq*G)
    qvalid_sg = jnp.repeat(qvalid, g, axis=1)

    def step(carry, tcol):  # tcol: (B,) physical ids of logical block j
        m, denom, acc = carry
        kj = k_arena[tcol].astype(jnp.float32)  # (B, bs, Hkv, Dh)
        vj = v_arena[tcol].astype(jnp.float32)
        pj = jnp.where((tcol == 0)[:, None], -1, pos_arena[tcol])  # (B, bs)
        s = jnp.einsum("bhgd,bkhd->bhgk", qh, kj,
                       preferred_element_type=jnp.float32)
        valid = pj[:, None, None, :] >= 0
        valid &= qvalid_sg[:, None, :, None]
        if causal:
            valid &= pj[:, None, None, :] <= qpos_sg[:, None, :, None]
        if window is not None:
            valid &= pj[:, None, None, :] > (qpos_sg[:, None, :, None]
                                             - window)
        s = jnp.where(valid, s, neg)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        denom = denom * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgk,bkhd->bhgd", p, vj, preferred_element_type=jnp.float32)
        return (m_new, denom, acc), None

    m0 = jnp.full((b, hkv, sg), neg, jnp.float32)
    l0 = jnp.zeros((b, hkv, sg), jnp.float32)
    a0 = jnp.zeros((b, hkv, sg, dh), jnp.float32)
    (_, denom, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), jnp.asarray(block_tables, jnp.int32).T)
    return acc / jnp.maximum(denom[..., None], 1e-30)


VALID_PAGED_IMPLS = ("pallas", "pallas_interpret", "xla")


def paged_attention_decode(
    q: jnp.ndarray,  # (B, S, H, Dh) — decode: S == 1
    k_arena: jnp.ndarray,  # (n_blocks, block_size, Hkv, Dh)
    v_arena: jnp.ndarray,
    pos_arena: jnp.ndarray,  # (n_blocks, block_size) int32, -1 invalid
    block_tables: jnp.ndarray,  # (B, nb) int32 physical ids, 0 = trash
    q_pos: jnp.ndarray,  # (B,) int32 first-query absolute positions
    *,
    q_lens: Optional[jnp.ndarray] = None,  # (B,) valid queries; None => S
    causal: bool = True,
    window: Optional[int] = None,
    impl: str = "xla",
) -> jnp.ndarray:
    """Paged GQA attention over the arena: returns (B, S, H, Dh) in
    ``q.dtype``. ``S == 1`` is plain decode; ``S > 1`` is the fused mixed
    step, where row ``b`` carries ``q_lens[b]`` real queries at absolute
    positions ``q_pos[b] + [0, q_lens[b])`` (decode rows 1, chunk rows up
    to S) and the padding queries' outputs are exact zeros.

    ``impl``: ``"pallas"`` (compiled kernel, TPU), ``"pallas_interpret"``
    (kernel body interpreted on CPU — validation only), or ``"xla"`` (the
    scan fallback, the fused serving path on non-TPU backends). All three
    share the masking contract and online-softmax math; parity against the
    gather path is pinned by ``tests/test_paged_attention.py``.
    """
    b, s, h, dh = q.shape
    hkv = k_arena.shape[2]
    g = h // hkv
    if q_lens is None:
        q_lens = jnp.full((b,), s, jnp.int32)
    # head index = hkv_idx * g + g_idx: the same (hkv, g) split the gather
    # path's full_attention uses, so outputs line up head-for-head. The
    # query axis folds in Sq-major ((q0 heads..., q1 heads...)) so the
    # kernel's score row i*G+g' maps back to query i of head group g'.
    q4 = (q.reshape(b, s, hkv, g, dh).transpose(0, 2, 1, 3, 4)
          .reshape(b, hkv, s * g, dh))
    if impl in ("pallas", "pallas_interpret"):
        out = _paged_attention_pallas(
            q4, k_arena, v_arena, pos_arena, block_tables, q_pos, q_lens,
            sq=s, causal=causal, window=window,
            interpret=(impl == "pallas_interpret"))
    elif impl == "xla":
        out = _paged_attention_xla(
            q4, k_arena, v_arena, pos_arena, block_tables, q_pos, q_lens,
            sq=s, causal=causal, window=window)
    else:
        raise ValueError(
            f"unknown paged attention impl {impl!r}; valid impls: "
            f"{', '.join(VALID_PAGED_IMPLS)}")
    return (out.reshape(b, hkv, s, g, dh).transpose(0, 2, 1, 3, 4)
            .reshape(b, s, h, dh).astype(q.dtype))
