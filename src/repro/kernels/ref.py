"""Pure-jnp oracles for the SWIS kernels.

``swis_matmul_ref`` computes the same function as the Pallas kernel from the
same packed operands — used by tests (assert_allclose across shape/dtype
sweeps) and as the CPU/dry-run fallback path inside models.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.packing import unpack_bits_u32


def dequant_ref(
    sign_plane: jnp.ndarray,
    mask_planes: jnp.ndarray,
    shifts: jnp.ndarray,
    scale: jnp.ndarray,
    *,
    group: int,
    dtype=jnp.float32,
    consecutive: bool = False,
    keep_slices=None,
) -> jnp.ndarray:
    """Dense (K, N) dequantized weights from packed planes (jnp, no Pallas).

    ``consecutive``: SWIS-C layout — ``shifts`` holds one offset byte per
    group and shift j = offset + j.
    ``keep_slices``: truncate to the k most significant bit-planes (plane
    shifts are ascending, so the top-k planes are the last k) — the
    reference for the kernel's truncated-precision draft execution.
    """
    n_shifts = mask_planes.shape[0]
    if keep_slices is not None and not 1 <= keep_slices <= n_shifts:
        raise ValueError(
            f"keep_slices must be in [1, {n_shifts}], got {keep_slices}")
    first = 0 if keep_slices is None else n_shifts - keep_slices
    k = sign_plane.shape[0] * 32
    sign = 1 - 2 * unpack_bits_u32(sign_plane)  # (K, N) int32
    acc = jnp.zeros(sign.shape, jnp.int32)
    for j in range(first, n_shifts):
        bits = unpack_bits_u32(mask_planes[j])
        if consecutive:
            s = shifts[:, :, 0].astype(jnp.int32) + j
        else:
            # inline nibble extraction: one slice+shift+mask per plane
            # (keeps the dequant's materialized-intermediate footprint
            # identical to the int8 layout while storing half the bytes)
            byte = shifts[:, :, j // 2].astype(jnp.int32)
            s = (byte >> (4 * (j % 2))) & 0xF
        s_full = jnp.broadcast_to(
            s[:, None, :], (k // group, group, s.shape[-1])
        ).reshape(k, -1)
        acc = acc + (bits << s_full)
    w = (sign * acc).astype(jnp.float32) * jnp.asarray(scale, jnp.float32).reshape(1, -1)
    return w.astype(dtype)


def swis_matmul_ref(
    x: jnp.ndarray,
    sign_plane: jnp.ndarray,
    mask_planes: jnp.ndarray,
    shifts: jnp.ndarray,
    scale: jnp.ndarray,
    *,
    group: int,
    consecutive: bool = False,
    keep_slices=None,
) -> jnp.ndarray:
    """Oracle for :func:`repro.kernels.swis_matmul.swis_matmul_packed`."""
    w = dequant_ref(sign_plane, mask_planes, shifts, scale, group=group,
                    dtype=x.dtype, consecutive=consecutive,
                    keep_slices=keep_slices)
    return jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
