"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) block.

Chunked SSD algorithm for training/prefill (sub-quadratic: O(L * chunk) +
O(L/chunk) state recurrence) and an O(1)-state recurrent step for decode —
this is the arch that carries the ``long_500k`` shape.

SWIS quantization applies to the in/out projections (GEMMs); the scan itself
is elementwise/small-tensor state math (noted in DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import dense, norm_apply
from repro.models.params import P


def _dims(cfg: ArchConfig):
    mc = cfg.mamba2
    d_inner = mc.expand * cfg.d_model
    n_heads = d_inner // mc.head_dim
    return d_inner, n_heads, mc.d_state, mc.head_dim


def build_mamba(cfg: ArchConfig) -> dict:
    mc = cfg.mamba2
    d = cfg.d_model
    d_inner, n_heads, d_state, _ = _dims(cfg)
    conv_dim = d_inner + 2 * d_state
    return {
        "in_proj": {"w": P((d, 2 * d_inner + 2 * d_state + n_heads),
                           ("embed", "mlp"))},
        "conv_w": P((mc.conv_width, conv_dim), (None, "mlp")),
        "A_log": P((n_heads,), (None,), init="zeros"),
        "D": P((n_heads,), (None,), init="ones"),
        "dt_bias": P((n_heads,), (None,), init="zeros"),
        "out_norm": {"scale": P((d_inner,), ("mlp",), init="ones")},
        "out_proj": {"w": P((d_inner, d), ("mlp", "embed"))},
    }


def build_mamba_cache(cfg: ArchConfig, batch: int, dtype) -> dict:
    mc = cfg.mamba2
    d_inner, n_heads, d_state, head_dim = _dims(cfg)
    conv_dim = d_inner + 2 * d_state
    return {
        "ssm": P((batch, n_heads, head_dim, d_state),
                 ("batch", "heads", None, None), init="zeros", dtype=jnp.float32),
        "conv": P((batch, mc.conv_width - 1, conv_dim),
                  ("batch", None, "mlp"), init="zeros", dtype=dtype),
    }


def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """Stable 'segment sum': out[..., i, j] = sum_{k in (j, i]} x[..., k].

    Lower-triangular (i >= j); -inf above the diagonal.
    """
    t = x.shape[-1]
    csum = jnp.cumsum(x, axis=-1)
    out = csum[..., :, None] - csum[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(
    x: jnp.ndarray,  # (B, L, H, P)
    dt: jnp.ndarray,  # (B, L, H) — post-softplus
    a_neg: jnp.ndarray,  # (H,) == -exp(A_log)  (negative decay rates)
    b_mat: jnp.ndarray,  # (B, L, N)
    c_mat: jnp.ndarray,  # (B, L, N)
    chunk: int,
    init_state: Optional[jnp.ndarray] = None,  # (B, H, P, N)
):
    """Chunked SSD (Mamba-2 alg. 1). Returns (y (B,L,H,P), final_state)."""
    bsz, l, h, p = x.shape
    n = b_mat.shape[-1]
    q = min(chunk, l)
    assert l % q == 0, (l, q)
    nc = l // q

    xb = (x * dt[..., None]).reshape(bsz, nc, q, h, p)  # fold dt into x
    ab = (dt * a_neg[None, None, :]).reshape(bsz, nc, q, h)  # log-decay per step
    bb = b_mat.reshape(bsz, nc, q, n)
    cb = c_mat.reshape(bsz, nc, q, n)

    ab_hl = ab.transpose(0, 1, 3, 2)  # (B, NC, H, Q)
    a_cum = jnp.cumsum(ab_hl, axis=-1)  # cumulative log decay within chunk

    # 1) Intra-chunk (diagonal blocks): Y_diag = (C B^T ⊙ L) X
    l_mat = jnp.exp(_segsum(ab_hl))  # (B, NC, H, Q, Q)
    scores = jnp.einsum("bcqn,bckn->bcqk", cb, bb)  # (B, NC, Q, Q)
    y_diag = jnp.einsum("bchqk,bcqk,bckhp->bcqhp", l_mat, scores, xb)

    # 2) Chunk summaries: state contributed by each chunk
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)  # (B, NC, H, Q)
    states = jnp.einsum("bckn,bchk,bckhp->bchpn", bb, decay_states, xb)

    # 3) Inter-chunk recurrence over chunk summaries
    chunk_decay = jnp.exp(a_cum[..., -1])  # (B, NC, H)

    def scan_fn(s_prev, inp):
        st, dec = inp
        s_new = s_prev * dec[..., None, None] + st
        return s_new, s_prev

    s0 = (jnp.zeros((bsz, h, p, n), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    final_state, prev_states = jax.lax.scan(
        scan_fn,
        s0,
        (states.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
         chunk_decay.transpose(1, 0, 2).astype(jnp.float32)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (B, NC, H, P, N)

    # 4) Chunk-input contribution: Y_off = C ⊙ decay_in @ prev_state
    decay_out = jnp.exp(a_cum)  # (B, NC, H, Q)
    y_off = jnp.einsum("bcqn,bchq,bchpn->bcqhp",
                       cb, decay_out, prev_states.astype(cb.dtype))

    y = (y_diag + y_off).reshape(bsz, l, h, p)
    return y, final_state


def ssd_decode_step(
    x: jnp.ndarray,  # (B, 1, H, P)
    dt: jnp.ndarray,  # (B, 1, H)
    a_neg: jnp.ndarray,  # (H,)
    b_mat: jnp.ndarray,  # (B, 1, N)
    c_mat: jnp.ndarray,  # (B, 1, N)
    state: jnp.ndarray,  # (B, H, P, N) fp32
):
    da = jnp.exp(dt[:, 0, :, None, None] * a_neg[None, :, None, None])
    upd = jnp.einsum("bhp,bn->bhpn", (x * dt[..., None])[:, 0],
                     b_mat[:, 0]).astype(jnp.float32)
    new_state = state * da + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state.astype(c_mat.dtype), c_mat[:, 0])
    return y[:, None], new_state


def _causal_conv(x, w, cache=None):
    """Depthwise causal conv along L. x: (B, L, C); w: (K, C)."""
    k = w.shape[0]
    if cache is not None:
        xp = jnp.concatenate([cache.astype(x.dtype), x], axis=1)
        new_cache = xp[:, -(k - 1):] if k > 1 else cache
    else:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
        new_cache = None
    out = jnp.zeros_like(x)
    s = x.shape[1]
    for i in range(k):
        out = out + xp[:, i : i + s] * w[i][None, None, :]
    return out, new_cache


def mamba_apply(
    p: dict,
    x: jnp.ndarray,  # (B, L, D)
    cfg: ArchConfig,
    cache: Optional[dict] = None,
):
    """Returns (y (B,L,D), new_cache_or_None)."""
    mc = cfg.mamba2
    d_inner, n_heads, d_state, head_dim = _dims(cfg)
    b, l, _ = x.shape
    dt_f = jnp.float32

    zxbcdt = dense(p["in_proj"], x, cfg)
    z, xc, bc, cc, dt_raw = jnp.split(
        zxbcdt,
        [d_inner, 2 * d_inner, 2 * d_inner + d_state, 2 * d_inner + 2 * d_state],
        axis=-1,
    )
    conv_in = jnp.concatenate([xc, bc, cc], axis=-1)
    conv_out, new_conv = _causal_conv(
        conv_in, p["conv_w"], None if cache is None else cache["conv"])
    conv_out = jax.nn.silu(conv_out)
    xc, bc, cc = jnp.split(conv_out, [d_inner, d_inner + d_state], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(dt_f) + p["dt_bias"].astype(dt_f))
    a_neg = -jnp.exp(p["A_log"].astype(dt_f))
    xh = xc.reshape(b, l, n_heads, head_dim)

    if cache is not None and l == 1:
        y, new_state = ssd_decode_step(xh.astype(dt_f), dt, a_neg,
                                       bc.astype(dt_f), cc.astype(dt_f),
                                       cache["ssm"])
        new_cache = {"ssm": new_state, "conv": new_conv}
    else:
        # train / prefill: pad L to a chunk multiple with dt=0 steps (decay 1,
        # zero input => state unaffected; padded outputs are sliced off).
        pad = (-l) % min(mc.chunk, l)
        xh_p = jnp.pad(xh.astype(dt_f), ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bc_p = jnp.pad(bc.astype(dt_f), ((0, 0), (0, pad), (0, 0)))
        cc_p = jnp.pad(cc.astype(dt_f), ((0, 0), (0, pad), (0, 0)))
        init = cache["ssm"] if cache is not None else None
        y, final_state = ssd_chunked(xh_p, dt_p, a_neg, bc_p, cc_p, mc.chunk,
                                     init_state=init)
        y = y[:, :l]
        new_cache = ({"ssm": final_state, "conv": new_conv}
                     if cache is not None else None)

    y = y + xh.astype(dt_f) * p["D"].astype(dt_f)[None, None, :, None]
    y = y.reshape(b, l, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)  # gated output
    y = norm_apply(p["out_norm"], y, cfg)
    return dense(p["out_proj"], y, cfg), new_cache
