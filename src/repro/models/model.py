"""Top-level model: embedding -> pattern-unit stack (scan + remat) -> norm ->
unembed, plus loss / prefill / decode entry points and dry-run input specs.

The depth axis is organized as ``n_units`` repetitions of the family's
pattern unit (scanned) plus ``tail`` unrolled layers, so heterogeneous
patterns (griffin 1:2, vlm cross-every-5) stay scan-compatible.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import params as pp
from repro.models import transformer as tfm
from repro.models.layers import build_embed, build_norm, embed_apply, norm_apply, unembed_apply
from repro.models.params import P
from repro.parallel.ctx import constrain


class Model:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.unit = tfm.pattern_for(cfg)
        u = len(self.unit)
        self.n_units = cfg.n_layers // u
        self.tail = tuple(self.unit[: cfg.n_layers % u])

    # ------------------------------------------------------------------
    # Parameter / cache trees (placeholders)
    # ------------------------------------------------------------------

    def build(self) -> dict:
        cfg = self.cfg
        unit_tree = {
            f"sub{i}_{kind}": tfm.build_block(cfg, kind)
            for i, kind in enumerate(self.unit)
        }
        tree = {
            "embed": build_embed(cfg),
            "blocks": pp.stack(unit_tree, self.n_units),
            "final_norm": build_norm(cfg.d_model),
        }
        if self.tail:
            tree["tail"] = {
                f"tail{i}_{kind}": tfm.build_block(cfg, kind)
                for i, kind in enumerate(self.tail)
            }
        if cfg.family == "encoder":
            # modality frontend stub: projects precomputed frame embeddings
            tree["frontend"] = {
                "w": P((cfg.d_model, cfg.d_model), ("embed", "embed2"))
            }
        return tree

    def build_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16,
                    per_slot: bool = False) -> dict:
        """``per_slot=True`` builds the continuous-batching layout: the
        position plane is (batch, cache_len) so every batch row (serving
        slot) decodes at its own depth (see repro.serve.kv_cache)."""
        cfg = self.cfg
        unit_cache = {
            f"sub{i}_{kind}": tfm.build_block_cache(cfg, kind, batch, max_len,
                                                    dtype, per_slot)
            for i, kind in enumerate(self.unit)
        }
        cache = {"blocks": pp.stack(unit_cache, self.n_units)}
        if self.tail:
            cache["tail"] = {
                f"tail{i}_{kind}": tfm.build_block_cache(cfg, kind, batch,
                                                         max_len, dtype,
                                                         per_slot)
                for i, kind in enumerate(self.tail)
            }
        return cache

    # ------------------------------------------------------------------
    # Forward
    # ------------------------------------------------------------------

    def _unit_apply(self, unit_params, x, *, positions, ctx, cache,
                    cache_index, block_tables=None, attend_cache=False,
                    paged=None, q_lens=None):
        new_cache = {} if cache is not None else None
        aux_sum = jnp.zeros((), jnp.float32)
        for i, kind in enumerate(self.unit):
            key = f"sub{i}_{kind}"
            c = cache[key] if cache is not None else None
            c = c if c else None  # empty dict => stateless block
            x, nc, aux = tfm.block_apply(
                unit_params[key], x, self.cfg, kind, positions=positions,
                ctx=ctx, cache=c, cache_index=cache_index,
                block_tables=block_tables, attend_cache=attend_cache,
                paged=paged, q_lens=q_lens)
            if cache is not None:
                new_cache[key] = nc if nc is not None else {}
            if "moe_aux" in aux:
                aux_sum = aux_sum + aux["moe_aux"]
        return x, new_cache, aux_sum

    def _stack_apply(self, params, x, *, positions, ctx=None, cache=None,
                     cache_index=None, block_tables=None,
                     attend_cache=False, paged=None, q_lens=None):
        cfg = self.cfg

        def unit_fn(x, unit_params, unit_cache):
            return self._unit_apply(
                unit_params, x, positions=positions, ctx=ctx,
                cache=unit_cache, cache_index=cache_index,
                block_tables=block_tables, attend_cache=attend_cache,
                paged=paged, q_lens=q_lens)

        if cfg.parallel.remat == "full":
            unit_fn = jax.checkpoint(unit_fn)
        elif cfg.parallel.remat == "dots":
            unit_fn = jax.checkpoint(
                unit_fn,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            )

        aux_total = jnp.zeros((), jnp.float32)
        if cfg.parallel.scan_layers and self.n_units > 1:
            if cache is not None:
                def body(carry, xs):
                    h, aux_acc = carry
                    unit_params, unit_cache = xs
                    h, nc, aux = unit_fn(h, unit_params, unit_cache)
                    return (h, aux_acc + aux), nc

                (x, aux_total), new_block_cache = jax.lax.scan(
                    body, (x, aux_total), (params["blocks"], cache["blocks"]))
            else:
                def body(carry, unit_params):
                    h, aux_acc = carry
                    h, _, aux = unit_fn(h, unit_params, None)
                    return (h, aux_acc + aux), None

                (x, aux_total), _ = jax.lax.scan(
                    body, (x, aux_total), params["blocks"])
                new_block_cache = None
        else:
            new_caches = []
            for i in range(self.n_units):
                unit_params = jax.tree.map(lambda a: a[i], params["blocks"])
                unit_cache = (jax.tree.map(lambda a: a[i], cache["blocks"])
                              if cache is not None else None)
                x, nc, aux = unit_fn(x, unit_params, unit_cache)
                aux_total = aux_total + aux
                new_caches.append(nc)
            new_block_cache = (
                jax.tree.map(lambda *xs: jnp.stack(xs), *new_caches)
                if cache is not None else None)

        new_cache = {"blocks": new_block_cache} if cache is not None else None

        if self.tail:
            if cache is not None:
                new_cache["tail"] = {}
            for i, kind in enumerate(self.tail):
                key = f"tail{i}_{kind}"
                c = cache["tail"][key] if cache is not None else None
                c = c if c else None
                x, nc, aux = tfm.block_apply(
                    params["tail"][key], x, cfg, kind, positions=positions,
                    ctx=ctx, cache=c, cache_index=cache_index,
                    block_tables=block_tables, attend_cache=attend_cache,
                    paged=paged, q_lens=q_lens)
                aux_total = aux_total + aux.get("moe_aux", 0.0)
                if cache is not None:
                    new_cache["tail"][key] = nc if nc is not None else {}
        return x, new_cache, aux_total

    def apply(self, params, batch: Dict[str, jnp.ndarray], *, cache=None,
              cache_index=None, last_only: bool = False, last_index=None,
              block_tables=None, attend_cache: bool = False, paged=None,
              q_lens=None):
        """Forward pass. batch: tokens (B,S) [or frames], optional patches.

        Returns (logits (B,S,V) — or (B,1,V) when last_only — new_cache,
        aux). ``last_only`` unembeds just the final position (prefill: the
        full-sequence logits are never needed, and the vocab-sharded
        unembedding over 32k positions is pure waste). ``last_index``
        (scalar or (B,) int32) unembeds just that position per row instead
        — bucket-padded prefills select the last *real* token.
        ``block_tables`` / ``attend_cache`` thread through to the attention
        cache paths (block-table decode / cached-prefix suffix prefill);
        ``q_lens`` ((B,) int32, with ``block_tables``) selects the fused
        mixed chunk+decode path (see :meth:`mixed_step`).
        """
        cfg = self.cfg
        dt = jnp.dtype(cfg.compute_dtype)
        if cfg.family == "encoder":
            x = batch["frames"].astype(dt) @ params["frontend"]["w"].astype(dt)
        else:
            x = embed_apply(params["embed"], batch["tokens"], cfg)
        s = x.shape[1]
        if cache_index is None:
            positions = jnp.arange(s, dtype=jnp.int32)
        elif jnp.ndim(cache_index) == 1:
            # per-slot decode: one write offset per batch row -> (B, S)
            positions = (cache_index[:, None]
                         + jnp.arange(s, dtype=jnp.int32)[None, :])
        else:
            positions = cache_index + jnp.arange(s, dtype=jnp.int32)
        ctx = batch.get("patches")
        if ctx is not None:
            ctx = ctx.astype(dt)
        x = constrain(x, ("batch", "seq", "embed"))
        x, new_cache, aux = self._stack_apply(
            params, x, positions=positions, ctx=ctx, cache=cache,
            cache_index=cache_index, block_tables=block_tables,
            attend_cache=attend_cache, paged=paged, q_lens=q_lens)
        if last_index is not None:
            b = x.shape[0]
            idx = jnp.broadcast_to(jnp.asarray(last_index, jnp.int32), (b,))
            x = x[jnp.arange(b), idx][:, None]
        elif last_only:
            x = x[:, -1:]
        x = norm_apply(params["final_norm"], x, cfg)
        logits = unembed_apply(params["embed"], x, cfg)
        return logits, new_cache, aux

    # ------------------------------------------------------------------
    # Loss / serve
    # ------------------------------------------------------------------

    def loss(self, params, batch) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        cfg = self.cfg
        logits, _, aux = self.apply(params, batch)
        labels = batch["labels"]
        mask = (labels >= 0).astype(jnp.float32)
        labels = jnp.maximum(labels, 0)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        denom = jnp.maximum(mask.sum(), 1.0)
        ce = -(ll * mask).sum() / denom
        total = ce
        if cfg.moe is not None:
            total = total + cfg.moe.router_aux_weight * aux
        metrics = {"loss": total, "ce": ce, "aux": aux,
                   "accuracy": ((jnp.argmax(logits, -1) == labels)
                                * mask).sum() / denom}
        return total, metrics

    def prefill(self, params, batch, cache):
        """Process a full prompt, fill the cache, return last-token logits."""
        logits, cache, _ = self.apply(params, batch, cache=cache,
                                      cache_index=jnp.int32(0),
                                      last_only=True)
        return logits[:, -1], cache

    def prefill_bucketed(self, params, batch, cache, last_index):
        """Whole-prompt prefill over bucket-padded tokens: identical to
        :meth:`prefill` except the returned logits are those of each row's
        last *real* token (``last_index``, scalar or (B,)). Pad tokens sit
        after every real token, so causal masking keeps real rows exact;
        the caller must invalidate the pad positions the cache recorded
        (``SlotKVCache.mask_pos_tail``) before the cache is decoded from."""
        logits, cache, _ = self.apply(params, batch, cache=cache,
                                      cache_index=jnp.int32(0),
                                      last_index=last_index)
        return logits[:, -1], cache

    def prefill_chunk(self, params, batch, cache, committed, last_index):
        """Prefill the next chunk of a partially-committed prompt: write
        the chunk's K/V into cache rows [committed, committed + S) and
        attend over the whole updated cache — rows [0, committed) already
        hold valid K/V (a cached prefix, previously prefilled chunks, or
        both; invalid rows are pos == -1 and masked as always). This is
        the single primitive behind both prefix-cache suffix prefill
        (committed = cached prefix length) and chunked prefill (committed
        advances one chunk at a time), bounding per-call work to the chunk
        size. Returns the logits of each row's last real token
        (``last_index``, chunk-relative)."""
        logits, cache, _ = self.apply(
            params, batch, cache=cache,
            cache_index=jnp.asarray(committed, jnp.int32),
            last_index=last_index, attend_cache=True)
        return logits[:, -1], cache

    def decode_step(self, params, token, cache, index, block_tables=None,
                    *, paged=None):
        """One decode step. token: (B, 1) int32; index: tokens-so-far — a
        scalar (lockstep batch) or a (B,) vector of per-slot positions
        (continuous batching over a per-slot cache). ``block_tables``
        ((B, n_blocks) int32) switches the cache to block-table
        indirection over a physical-block arena (prefix caching);
        ``paged`` additionally fuses the block-table gather into the
        paged-attention decode kernel (impl name, see
        :mod:`repro.kernels.paged_attention`)."""
        logits, cache, _ = self.apply(params, {"tokens": token}, cache=cache,
                                      cache_index=index,
                                      block_tables=block_tables, paged=paged)
        return logits[:, -1], cache

    def mixed_step(self, params, batch, cache, start, q_lens, last_index,
                   block_tables, *, paged=None):
        """One fused mixed chunk+decode step over the block arena: row
        ``r`` of ``batch['tokens']`` ((B, S) int32) carries ``q_lens[r]``
        real tokens starting at absolute position ``start[r]`` — decode
        rows hold one token (their next decode position), the prefill
        chunk's rows hold up to S prompt tokens at the group's committed
        offset, idle rows hold none. Each row's valid K/V is
        scatter-committed into the arena *through its block table inside
        this same launch* (``serve/kv_cache.scatter_row`` never runs for
        fused chunks), attention reads the arena through the tables
        (``paged`` fuses the gather away entirely), and the returned
        logits are each row's ``last_index`` position — one device
        dispatch where the separate path pays a ``prefill_chunk`` launch
        plus a ``decode_step`` launch."""
        logits, cache, _ = self.apply(
            params, batch, cache=cache,
            cache_index=jnp.asarray(start, jnp.int32),
            last_index=last_index, block_tables=block_tables,
            paged=paged, q_lens=jnp.asarray(q_lens, jnp.int32))
        return logits[:, -1], cache

    def verify_step(self, params, batch, cache, start, q_lens,
                    block_tables, *, paged=None):
        """Speculative-verify launch: score ALL positions of a multi-token
        batch in one dispatch. Same per-row ``q_lens`` routing as
        :meth:`mixed_step` — row ``r`` feeds ``q_lens[r]`` tokens starting
        at absolute position ``start[r]`` ([bonus token, draft_1..draft_k]
        for a speculating slot, 0 for idle rows), each row's K/V is
        committed through its block table inside the launch (invalid
        tokens route to the trash block) — but the returned logits are the
        full (B, S, V) tensor instead of one position per row: position j
        of row r is the next-token distribution after its first j+1 fed
        tokens, exactly what the accept-prefix rule compares draft tokens
        against. Costs a norm+unembed over all S positions (S = spec_k+1,
        so the extra unembed work is a few rows, not a prefill's worth).
        """
        logits, cache, _ = self.apply(
            params, batch, cache=cache,
            cache_index=jnp.asarray(start, jnp.int32),
            block_tables=block_tables, paged=paged,
            q_lens=jnp.asarray(q_lens, jnp.int32))
        return logits, cache

    # ------------------------------------------------------------------
    # Dry-run input specs
    # ------------------------------------------------------------------

    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
        cfg = self.cfg
        b = shape.global_batch
        s = shape.seq_len
        dt = jnp.dtype(cfg.compute_dtype)
        if shape.kind == "train":
            if cfg.family == "encoder":
                specs = {
                    "frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), dt),
                    "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
                }
            else:
                specs = {
                    "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
                    "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
                }
            if cfg.family == "vlm":
                specs["patches"] = jax.ShapeDtypeStruct(
                    (b, cfg.vlm.n_patches, cfg.vlm.vision_dim), dt)
            return specs
        if shape.kind == "prefill":
            if cfg.family == "encoder":
                specs = {"frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), dt)}
            else:
                specs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
            if cfg.family == "vlm":
                specs["patches"] = jax.ShapeDtypeStruct(
                    (b, cfg.vlm.n_patches, cfg.vlm.vision_dim), dt)
            return specs
        if shape.kind == "decode":
            specs = {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
            if cfg.family == "vlm":
                specs["patches"] = jax.ShapeDtypeStruct(
                    (b, cfg.vlm.n_patches, cfg.vlm.vision_dim), dt)
            return specs
        raise ValueError(shape.kind)
