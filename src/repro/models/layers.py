"""Basic model layers (pure JAX, placeholder-tree params).

Every GEMM goes through :func:`dense`, which applies the SWIS quantization
policy (QAT fake-quant / PTQ / off) — the paper's technique is a first-class
feature of every architecture.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.qat import maybe_quant
from repro.models.params import P


# ---------------------------------------------------------------------------
# Builders (placeholder trees)
# ---------------------------------------------------------------------------


def build_norm(d: int) -> dict:
    return {"scale": P((d,), ("embed",), init="ones")}


def build_linear(d_in: int, d_out: int, axes=("embed", "mlp"), scale=None) -> dict:
    return {"w": P((d_in, d_out), axes, scale=scale)}


def build_mlp(cfg: ArchConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    p = {
        "wo": build_linear(f, d, ("mlp", "embed")),
        "wi": build_linear(d, f, ("embed", "mlp")),
    }
    if cfg.glu:
        p["wg"] = build_linear(d, f, ("embed", "mlp"))
    return p


def build_embed(cfg: ArchConfig) -> dict:
    v, d = cfg.padded_vocab, cfg.d_model
    p = {"tok": P((v, d), ("vocab", "embed"), init="embed", scale=0.02)}
    if not cfg.tie_embeddings:
        p["unembed"] = P((d, v), ("embed", "vocab"), scale=0.02)
    return p


# ---------------------------------------------------------------------------
# Appliers
# ---------------------------------------------------------------------------


def norm_apply(p: dict, x: jnp.ndarray, cfg: ArchConfig, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    if cfg.norm == "rms":
        var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(var + eps)
    else:  # LayerNorm without bias
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def dense(p: dict, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """Linear layer with the SWIS quantization policy applied to the weight.

    Packed-serving path: when the leaf is a packed SWIS dict (see
    repro.serve.quantized), the matmul consumes the compressed bit-planes —
    the Pallas kernel dequantizes in VMEM on TPU; the jnp reference path
    does the same math on CPU/dry-run with identical packed HBM operands.
    """
    w = p["w"]
    if isinstance(w, dict) and "mask_planes" in w:
        from repro.kernels import ops
        from repro.core.packing import PackedWeight

        k = w["sign_plane"].shape[0] * 32
        method = ("swis_c" if cfg.quant.cfg.method == "swis_c" else "swis")
        pw = PackedWeight(
            sign_plane=w["sign_plane"], mask_planes=w["mask_planes"],
            shifts=w["shifts"], scale=w["scale"],
            group_size=k // w["shifts"].shape[0],
            n_shifts=int(w["mask_planes"].shape[0]), k=k,
            c=w["sign_plane"].shape[1], method=method)
        return ops.swis_matmul(
            x, pw, use_pallas=False,
            keep_slices=cfg.quant.keep_slices).astype(x.dtype)
    if cfg.quant.act_shifts:
        from repro.core.swis import act_truncate

        x = act_truncate(x, cfg.quant.act_shifts)
    w = maybe_quant(w, cfg.quant.cfg, cfg.quant.mode)
    return x @ w.astype(x.dtype)


def _act(h: jnp.ndarray, kind: str) -> jnp.ndarray:
    return jax.nn.silu(h) if kind == "silu" else jax.nn.gelu(h)


def mlp_apply(p: dict, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    h = _act(dense(p["wi"], x, cfg), cfg.act)
    if cfg.glu:
        h = h * dense(p["wg"], x, cfg)
    return dense(p["wo"], h, cfg)


def embed_apply(p: dict, tokens: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    e = p["tok"]
    if cfg.quant.quantize_embeddings:
        e = maybe_quant(e, cfg.quant.cfg, cfg.quant.mode)
    dt = jnp.dtype(cfg.compute_dtype)
    return jnp.take(e, tokens, axis=0).astype(dt)


def unembed_apply(p: dict, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    if cfg.tie_embeddings:
        w = p["tok"].T
    else:
        w = p["unembed"]
    # logits in fp32 for a stable softmax-CE
    return (x @ w.astype(x.dtype)).astype(jnp.float32)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding. x: (..., S, n_heads, d_head); positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)
