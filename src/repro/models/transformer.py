"""Block assembly: every architecture family is a repeating *pattern unit* of
sub-blocks, scanned over the depth axis (bounded HLO for 88-layer models),
with any remainder layers unrolled as a tail.

Kinds:
  attn        pre-norm self-attention + MLP              (dense / vlm self)
  enc         bidirectional self-attention + MLP         (hubert)
  attn_local  sliding-window self-attention + MLP        (griffin 1:2 pattern)
  moe         self-attention + mixture-of-experts FFN    (qwen2-moe / dbrx)
  rec         RG-LRU temporal-mix + MLP                  (griffin)
  mamba       Mamba-2 SSD mixer (no MLP)                 (mamba2)
  self_cross  self-attn + gated cross-attn + MLP         (llama-3.2-vision)
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import build_mlp, build_norm, mlp_apply, norm_apply
from repro.models.params import P
from repro.parallel.ctx import constrain


def pattern_for(cfg: ArchConfig) -> Tuple[str, ...]:
    if cfg.family == "dense":
        return ("attn",)
    if cfg.family == "moe":
        return ("moe",)
    if cfg.family == "griffin":
        return cfg.griffin.pattern
    if cfg.family == "mamba2":
        return ("mamba",)
    if cfg.family == "encoder":
        return ("enc",)
    if cfg.family == "vlm":
        n = cfg.vlm.cross_every
        return ("attn",) * (n - 1) + ("self_cross",)
    raise ValueError(cfg.family)


def build_block(cfg: ArchConfig, kind: str) -> dict:
    d = cfg.d_model
    if kind in ("attn", "enc", "attn_local"):
        return {
            "ln1": build_norm(d),
            "attn": attn_mod.build_attention(cfg),
            "ln2": build_norm(d),
            "mlp": build_mlp(cfg),
        }
    if kind == "moe":
        return {
            "ln1": build_norm(d),
            "attn": attn_mod.build_attention(cfg),
            "ln2": build_norm(d),
            "moe": moe_mod.build_moe(cfg),
        }
    if kind == "rec":
        return {
            "ln1": build_norm(d),
            "rec": rglru_mod.build_rglru_block(cfg),
            "ln2": build_norm(d),
            "mlp": build_mlp(cfg),
        }
    if kind == "mamba":
        return {"ln": build_norm(d), "mixer": ssm_mod.build_mamba(cfg)}
    if kind == "self_cross":
        return {
            "ln1": build_norm(d),
            "attn": attn_mod.build_attention(cfg),
            "lnx": build_norm(d),
            "xattn": attn_mod.build_attention(cfg, kind="cross"),
            "xgate": P((), (), init="zeros"),
            "ln2": build_norm(d),
            "mlp": build_mlp(cfg),
        }
    raise ValueError(kind)


def build_block_cache(cfg: ArchConfig, kind: str, batch: int, max_len: int,
                      dtype, per_slot: bool = False) -> dict:
    if kind in ("attn", "moe", "self_cross"):
        c = attn_mod.build_cache(cfg, batch, max_len, dtype)
    elif kind == "attn_local":
        c = attn_mod.build_cache(cfg, batch, min(max_len, cfg.griffin.window),
                                 dtype)
    elif kind == "rec":
        return rglru_mod.build_rglru_cache(cfg, batch, dtype)
    elif kind == "mamba":
        return ssm_mod.build_mamba_cache(cfg, batch, dtype)
    elif kind == "enc":
        return {}
    else:
        raise ValueError(kind)
    cache_len = c["k"].shape[1]
    # position slots start invalid (-1) so unwritten cache entries are masked.
    # per_slot: each batch row (serving slot) tracks its own occupancy so
    # requests at different decode depths can share one batched cache.
    if per_slot:
        c["pos"] = P((batch, cache_len), ("batch", "kv_seq"), init="fill",
                     scale=-1, dtype=jnp.int32)
    else:
        c["pos"] = P((cache_len,), ("kv_seq",), init="fill", scale=-1,
                     dtype=jnp.int32)
    return c


def block_apply(
    p: dict,
    x: jnp.ndarray,
    cfg: ArchConfig,
    kind: str,
    *,
    positions: jnp.ndarray,
    ctx: Optional[jnp.ndarray] = None,
    cache: Optional[dict] = None,
    cache_index: Optional[jnp.ndarray] = None,
    block_tables: Optional[jnp.ndarray] = None,
    attend_cache: bool = False,
    paged: Optional[str] = None,
    q_lens: Optional[jnp.ndarray] = None,
):
    """Returns (x, new_cache, aux)."""
    aux = {}
    new_cache = None

    if kind == "mamba":
        h, new_cache = ssm_mod.mamba_apply(
            p["mixer"], norm_apply(p["ln"], x, cfg), cfg, cache)
        x = x + h
        x = constrain(x, ("batch", "seq", "embed"))
        return x, new_cache, aux

    if kind == "rec":
        h, new_cache = rglru_mod.rglru_apply(
            p["rec"], norm_apply(p["ln1"], x, cfg), cfg, cache)
        x = x + h
        x = x + mlp_apply(p["mlp"], norm_apply(p["ln2"], x, cfg), cfg)
        x = constrain(x, ("batch", "seq", "embed"))
        return x, new_cache, aux

    causal = cfg.causal and kind != "enc"
    window = cfg.griffin.window if kind == "attn_local" else None
    h, new_cache = attn_mod.attention_apply(
        p["attn"],
        norm_apply(p["ln1"], x, cfg),
        cfg,
        positions=positions,
        causal=causal,
        window=window,
        cache=cache,
        cache_index=cache_index,
        block_tables=block_tables,
        attend_cache=attend_cache,
        paged=paged,
        q_lens=q_lens,
    )
    x = x + h

    if kind == "self_cross" and ctx is not None:
        hx, _ = attn_mod.attention_apply(
            p["xattn"],
            norm_apply(p["lnx"], x, cfg),
            cfg,
            positions=positions,
            causal=False,
            ctx=ctx,
        )
        x = x + jnp.tanh(p["xgate"]).astype(x.dtype) * hx

    if kind == "moe":
        h, aux = moe_mod.moe_apply(p["moe"], norm_apply(p["ln2"], x, cfg), cfg)
        x = x + h
    else:
        x = x + mlp_apply(p["mlp"], norm_apply(p["ln2"], x, cfg), cfg)

    x = constrain(x, ("batch", "seq", "embed"))
    return x, new_cache, aux
