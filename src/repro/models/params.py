"""Parameter placeholder trees.

Model ``build_*`` functions return trees of :class:`P` placeholders (shape +
logical axes + initializer). Materializers turn one placeholder tree into

* concrete parameters (:func:`init_params`),
* ``jax.ShapeDtypeStruct`` stand-ins (:func:`abstract_params`, dry-run),
* ``PartitionSpec`` trees (:mod:`repro.parallel.sharding`),

so the parameter tree and its sharding tree are congruent by construction.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class P:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]  # logical axis names, len == ndim
    init: str = "normal"  # 'normal' | 'zeros' | 'ones' | 'embed'
    scale: Optional[float] = None  # stddev override for 'normal'
    dtype: Any = None  # param dtype override

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_placeholder(x) -> bool:
    return isinstance(x, P)


def stack(tree, n: int, axis_name: str = "layers"):
    """Prepend a stacked-layers axis to every placeholder in the tree."""
    return jax.tree.map(
        lambda p: P((n,) + p.shape, (axis_name,) + p.axes, p.init, p.scale, p.dtype),
        tree,
        is_leaf=is_placeholder,
    )


def _leaf_rng(root: jax.Array, path) -> jax.Array:
    key = root
    for part in path:
        token = getattr(part, "key", None) or str(getattr(part, "idx", part))
        key = jax.random.fold_in(key, np.uint32(abs(hash(token)) % (2 ** 31)))
    return key


def init_params(tree, rng: jax.Array, dtype=jnp.float32):
    """Materialize a placeholder tree into concrete parameters."""

    def make(path, p: P):
        dt = p.dtype or dtype
        if p.init == "zeros":
            return jnp.zeros(p.shape, dt)
        if p.init == "ones":
            return jnp.ones(p.shape, dt)
        if p.init == "fill":
            return jnp.full(p.shape, p.scale, dt)
        key = _leaf_rng(rng, path)
        if p.init == "embed":
            std = p.scale if p.scale is not None else 1.0
            return (jax.random.normal(key, p.shape) * std).astype(dt)
        # fan-in scaled truncated-normal-ish init
        fan_in = p.shape[-2] if len(p.shape) >= 2 else p.shape[-1]
        std = p.scale if p.scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, p.shape) * std).astype(dt)

    return jax.tree_util.tree_map_with_path(make, tree, is_leaf=is_placeholder)


def abstract_params(tree, dtype=jnp.float32):
    """ShapeDtypeStruct stand-ins (no allocation) — dry-run path."""
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype or dtype),
        tree,
        is_leaf=is_placeholder,
    )


def map_placeholders(fn, tree):
    return jax.tree.map(fn, tree, is_leaf=is_placeholder)


def count_params(tree) -> int:
    total = 0
    for leaf in jax.tree.leaves(tree, is_leaf=is_placeholder):
        total += int(np.prod(leaf.shape))
    return total
