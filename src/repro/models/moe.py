"""Mixture-of-Experts FFN: top-k routing, GShard-style capacity dispatch,
optional shared (always-on) experts — covers Qwen2-MoE (60e top-4 + 4 shared,
fine-grained d_ff) and DBRX (16e top-4).

Expert parallelism: dispatch/combine einsums are annotated with the 'expert'
logical axis; the sharding rules map it to the mesh 'model' axis when the
expert count divides it (EP), otherwise experts keep their hidden dim sharded
(TP). Router math in fp32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.qat import maybe_quant
from repro.models.layers import _act
from repro.models.params import P


def _expert_dff(cfg: ArchConfig) -> int:
    return cfg.moe.d_ff_expert or cfg.d_ff


def build_moe(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    e = cfg.moe.e_total  # includes EP-divisibility padding
    f = _expert_dff(cfg)
    p = {
        "router": P((d, e), ("embed", "expert"), scale=0.02),
        "wi": P((e, d, f), ("expert", "embed", "mlp")),
        "wo": P((e, f, d), ("expert", "mlp", "embed")),
    }
    if cfg.glu:
        p["wg"] = P((e, d, f), ("expert", "embed", "mlp"))
    if cfg.moe.n_shared:
        fs = f * cfg.moe.n_shared
        p["shared_wi"] = P((d, fs), ("embed", "mlp"))
        p["shared_wo"] = P((fs, d), ("mlp", "embed"))
        if cfg.glu:
            p["shared_wg"] = P((d, fs), ("embed", "mlp"))
    return p


def _quant(w, cfg: ArchConfig):
    if isinstance(w, dict) and "mask_planes" in w:  # packed serving leaf
        from repro.serve.quantized import dequant_leaf

        return dequant_leaf(w, dtype=jnp.dtype(cfg.compute_dtype),
                            consecutive=cfg.quant.cfg.method == "swis_c")
    if w.ndim == 3:  # per-expert: quantize each expert matrix independently
        if cfg.quant.cfg.method == "none" or cfg.quant.mode == "off":
            return w
        return jax.vmap(lambda m: maybe_quant(m, cfg.quant.cfg, cfg.quant.mode))(w)
    return maybe_quant(w, cfg.quant.cfg, cfg.quant.mode)


def moe_apply(p: dict, x: jnp.ndarray, cfg: ArchConfig):
    """x: (B, S, D) -> (y, aux_metrics)."""
    mc = cfg.moe
    b, s, d = x.shape
    e = mc.n_experts
    f = _expert_dff(cfg)
    dt = x.dtype

    tokens = x.reshape(-1, d)
    t = tokens.shape[0]

    e_total = mc.e_total

    if s == 1:
        # Decode: dropless dense dispatch (capacity dropping at batch-1
        # token counts would diverge from training numerics). T is small.
        logits = tokens.astype(jnp.float32) @ p["router"].astype(jnp.float32)
        if e_total > e:
            logits = logits.at[:, e:].set(-1e30)  # padded experts: unroutable
        probs = jax.nn.softmax(logits, axis=-1)  # (t, e)
        gate_vals, gate_idx = jax.lax.top_k(probs, mc.top_k)
        gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
        comb = jnp.zeros((t, e_total), jnp.float32).at[
            jnp.arange(t)[:, None], gate_idx].add(gate_vals)
        wi = _quant(p["wi"], cfg).astype(dt)
        h = jnp.einsum("td,edf->tef", tokens, wi)
        h = _act(h, cfg.act)
        if "wg" in p:
            wg = _quant(p["wg"], cfg).astype(dt)
            h = h * jnp.einsum("td,edf->tef", tokens, wg)
        wo = _quant(p["wo"], cfg).astype(dt)
        ye = jnp.einsum("tef,efd->ted", h, wo)
        y = jnp.einsum("te,ted->td", comb.astype(dt), ye)
        if "shared_wi" in p:
            hs = _act(tokens @ _quant(p["shared_wi"], cfg).astype(dt), cfg.act)
            if "shared_wg" in p:
                hs = hs * (tokens @ _quant(p["shared_wg"], cfg).astype(dt))
            y = y + hs @ _quant(p["shared_wo"], cfg).astype(dt)
        return y.reshape(b, s, d), {"moe_aux": jnp.zeros((), jnp.float32)}

    gs = min(mc.group_tokens, t)
    if t % gs:
        gs = t  # fall back to one group (smoke-scale inputs)
    g = t // gs
    xt = tokens.reshape(g, gs, d)

    # --- Router (fp32) ---
    logits = (xt.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    if e_total > e:
        logits = jnp.concatenate(
            [logits[..., :e], jnp.full_like(logits[..., e:], -1e30)], axis=-1)
    probs = jax.nn.softmax(logits, axis=-1)  # (g, gs, e_total)
    gate_vals, gate_idx = jax.lax.top_k(probs, mc.top_k)  # (g, gs, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # --- Capacity + position bookkeeping (GShard) ---
    cap = max(int(gs * mc.top_k * mc.capacity_factor / e), 1)
    onehot = jax.nn.one_hot(gate_idx, e_total, dtype=jnp.float32)
    # priority: k-th choice of earlier tokens first
    flat = onehot.transpose(0, 2, 1, 3).reshape(g, mc.top_k * gs, e_total)
    pos = jnp.cumsum(flat, axis=1) - flat  # position within expert
    keep = pos < cap
    flat = flat * keep
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32) * flat[..., None]
    pos_oh = pos_oh.reshape(g, mc.top_k, gs, e_total, cap).transpose(0, 2, 1, 3, 4)
    # (g, gs, e, cap) combine weights; dispatch mask
    combine = (gate_vals[..., None, None] * pos_oh).sum(axis=2)
    dispatch = (combine > 0).astype(dt)

    # --- Expert computation (EP-shardable einsums) ---
    xd = jnp.einsum("gsec,gsd->egcd", dispatch, xt)  # (e, g, cap, d)
    wi = _quant(p["wi"], cfg).astype(dt)
    h = jnp.einsum("egcd,edf->egcf", xd, wi)
    h = _act(h, cfg.act)
    if "wg" in p:
        wg = _quant(p["wg"], cfg).astype(dt)
        h = h * jnp.einsum("egcd,edf->egcf", xd, wg)
    wo = _quant(p["wo"], cfg).astype(dt)
    yo = jnp.einsum("egcf,efd->egcd", h, wo)
    y = jnp.einsum("gsec,egcd->gsd", combine.astype(dt), yo)

    # --- Shared experts ---
    if "shared_wi" in p:
        hs = _act(xt @ _quant(p["shared_wi"], cfg).astype(dt), cfg.act)
        if "shared_wg" in p:
            hs = hs * (xt @ _quant(p["shared_wg"], cfg).astype(dt))
        y = y + hs @ _quant(p["shared_wo"], cfg).astype(dt)

    # --- Aux load-balancing loss (Switch-style) ---
    density = flat.reshape(g, mc.top_k, gs, e_total).sum(axis=(1, 2)) / gs
    router_prob = probs.mean(axis=1)  # (g, e)
    aux = (density * router_prob).sum(-1).mean() * e

    return y.reshape(b, s, d), {"moe_aux": aux}
