"""Griffin / RecurrentGemma RG-LRU recurrent block (arXiv:2402.19427).

Recurrence:  h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t^2) ⊙ (i_t ⊙ x_t)
with         a_t = exp(-c * softplus(Λ) * σ(W_a x_t)),  i_t = σ(W_x x_t).

Training/prefill uses ``jax.lax.associative_scan`` (parallel, O(log L) depth);
decode keeps the O(1) per-token recurrent state — together with the bounded
local-attention window this makes the arch sub-quadratic (long_500k shape).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import dense
from repro.models.params import P
from repro.models.ssm import _causal_conv


def _n_blocks(cfg: ArchConfig) -> int:
    # Griffin uses block-diagonal RG-LRU gate matrices (one block per head).
    gc = cfg.griffin
    nb = cfg.n_heads
    while gc.lru_width % nb:
        nb -= 1
    return nb


def build_rglru_block(cfg: ArchConfig) -> dict:
    gc = cfg.griffin
    d, w = cfg.d_model, gc.lru_width
    nb = _n_blocks(cfg)
    bs = w // nb
    return {
        "in_x": {"w": P((d, w), ("embed", "mlp"))},
        "in_gate": {"w": P((d, w), ("embed", "mlp"))},
        "conv_w": P((gc.conv_width, w), (None, "mlp")),
        "gate_a": P((nb, bs, bs), ("heads", None, None)),
        "gate_x": P((nb, bs, bs), ("heads", None, None)),
        "lambda_raw": P((w,), ("mlp",), init="ones"),
        "out": {"w": P((w, d), ("mlp", "embed"))},
    }


def _block_gate(w_blocks: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Block-diagonal matmul: x (B, L, W) @ blockdiag(w_blocks (NB, BS, BS))."""
    b, l, w = x.shape
    nb, bs, _ = w_blocks.shape
    xb = x.reshape(b, l, nb, bs)
    y = jnp.einsum("blni,nij->blnj", xb, w_blocks.astype(x.dtype))
    return y.reshape(b, l, w)


def build_rglru_cache(cfg: ArchConfig, batch: int, dtype) -> dict:
    gc = cfg.griffin
    return {
        "h": P((batch, gc.lru_width), ("batch", "mlp"), init="zeros",
               dtype=jnp.float32),
        "conv": P((batch, gc.conv_width - 1, gc.lru_width),
                  ("batch", None, "mlp"), init="zeros", dtype=dtype),
    }


def _rglru_scan(log_a: jnp.ndarray, b: jnp.ndarray,
                h0: Optional[jnp.ndarray]):
    """h_t = exp(log_a_t) * h_{t-1} + b_t via associative scan over axis 1."""
    if h0 is not None:
        # fold the initial state into step 0: h_0 = exp(log_a_0)*h0 + b_0
        b = b.at[:, 0].add(jnp.exp(log_a[:, 0]) * h0)

    def combine(left, right):
        la, ba = left
        lb, bb = right
        return la + lb, jnp.exp(lb) * ba + bb

    log_acc, h = jax.lax.associative_scan(combine, (log_a, b), axis=1)
    return h


def rglru_apply(
    p: dict,
    x: jnp.ndarray,  # (B, L, D)
    cfg: ArchConfig,
    cache: Optional[dict] = None,
):
    """Griffin recurrent block. Returns (y (B,L,D), new_cache_or_None)."""
    gc = cfg.griffin
    b, l, _ = x.shape
    f32 = jnp.float32

    gate_branch = jax.nn.gelu(dense(p["in_gate"], x, cfg))
    xb = dense(p["in_x"], x, cfg)
    xb, new_conv = _causal_conv(
        xb, p["conv_w"], None if cache is None else cache["conv"])

    # RG-LRU gates (block-diagonal; fp32 recurrence)
    r = jax.nn.sigmoid(_block_gate(p["gate_a"], xb).astype(f32))
    i = jax.nn.sigmoid(_block_gate(p["gate_x"], xb).astype(f32))
    log_lambda = -jax.nn.softplus(p["lambda_raw"].astype(f32))  # log a_base < 0
    log_a = gc.lru_c * log_lambda[None, None, :] * r  # (B, L, W) log decay
    a2 = jnp.exp(2.0 * log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12)) * i * xb.astype(f32)

    if cache is None:
        h = _rglru_scan(log_a, gated_in, None)
        new_cache = None
    elif l == 1:
        h = jnp.exp(log_a[:, 0]) * cache["h"] + gated_in[:, 0]
        new_cache = {"h": h, "conv": new_conv}
        h = h[:, None]
    else:  # chunked prefill with carried state
        h = _rglru_scan(log_a, gated_in, cache["h"])
        new_cache = {"h": h[:, -1], "conv": new_conv}

    y = h.astype(x.dtype) * gate_branch
    return dense(p["out"], y, cfg), new_cache
