"""Attention: GQA/MHA/MQA, local (sliding-window), cross-attention, KV cache.

Training/prefill uses a KV-chunked online-softmax (flash-style) scan so the
S x S score matrix is never materialized — memory O(S * chunk). Decode uses a
single einsum over the (sharded) cache.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels.paged_attention import mask_value, paged_attention_decode
from repro.models.layers import build_linear, dense, rope
from repro.models.params import P


def build_attention(cfg: ArchConfig, kind: str = "self") -> dict:
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    kv_in = cfg.vlm.vision_dim if (kind == "cross" and cfg.vlm) else d
    return {
        "wq": build_linear(d, h * dh, ("embed", "q_proj")),
        "wk": build_linear(kv_in, hkv * dh, ("embed", "kv_proj")),
        "wv": build_linear(kv_in, hkv * dh, ("embed", "kv_proj")),
        "wo": build_linear(h * dh, d, ("q_proj", "embed")),
    }


def build_cache(cfg: ArchConfig, batch: int, max_len: int, dtype) -> dict:
    """K/V planes. The position plane is added by ``build_block_cache`` —
    shared (cache_len,) for the static engine, per-slot (batch, cache_len)
    for continuous batching."""
    hkv, dh = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": P((batch, max_len, hkv, dh), ("batch", "kv_seq", "kv_heads", "head_dim"),
               init="zeros", dtype=dtype),
        "v": P((batch, max_len, hkv, dh), ("batch", "kv_seq", "kv_heads", "head_dim"),
               init="zeros", dtype=dtype),
    }


def _split_heads(x, n_heads, d_head):
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, d_head)


def chunked_attention(
    q: jnp.ndarray,  # (B, Sq, H, Dh)
    k: jnp.ndarray,  # (B, Skv, Hkv, Dh)
    v: jnp.ndarray,
    *,
    q_pos: jnp.ndarray,  # (Sq,) int32, or (B, Sq) per-slot
    kv_pos: jnp.ndarray,  # (Skv,) int32, or (B, Skv) per-slot; neg => padding
    causal: bool,
    window: Optional[int],
    chunk: int,
) -> jnp.ndarray:
    b, sq, h, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    chunk = min(chunk, skv)
    # 2-D positions carry a per-batch (slot) row, e.g. a cached-prefix
    # suffix prefill attending over a per-slot cache.
    kp = kv_pos if kv_pos.ndim == 2 else kv_pos[None, :]  # (1 | B, Skv)
    qp = q_pos if q_pos.ndim == 2 else q_pos[None, :]  # (1 | B, Sq)
    if skv % chunk:
        pad = (-skv) % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kp = jnp.pad(kp, ((0, 0), (0, pad)), constant_values=-1)
        skv += pad
    n_chunks = skv // chunk

    qh = q.reshape(b, sq, hkv, g, dh).astype(jnp.float32) * (dh ** -0.5)
    kc = k.reshape(b, n_chunks, chunk, hkv, dh)
    vc = v.reshape(b, n_chunks, chunk, hkv, dh)
    pc = kp.reshape(kp.shape[0], n_chunks, chunk)

    def step(carry, xs):
        m, denom, acc = carry
        k_c, v_c, p_c = xs  # p_c: (1 | B, chunk)
        s = jnp.einsum(
            "bqhgd,bkhd->bhgqk",
            qh,
            k_c.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        valid = p_c[:, None, :] >= 0
        if causal:
            valid = valid & (p_c[:, None, :] <= qp[:, :, None])
        if window is not None:
            valid = valid & (p_c[:, None, :] > qp[:, :, None] - window)
        s = jnp.where(valid[:, None, None], s, mask_value(s.dtype))
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        denom_new = denom * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum(
            "bhgqk,bkhd->bhgqd",
            p,
            v_c.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        acc_new = acc * alpha[..., None] + pv
        return (m_new, denom_new, acc_new), None

    m0 = jnp.full((b, hkv, g, sq), mask_value(jnp.float32), jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, sq, dh), jnp.float32)
    (m, denom, acc), _ = jax.lax.scan(
        step,
        (m0, l0, a0),
        (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4),
         pc.transpose(1, 0, 2)),
    )
    out = acc / jnp.maximum(denom[..., None], 1e-30)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dh).astype(q.dtype)


def full_attention(
    q: jnp.ndarray,  # (B, Sq, H, Dh) — decode: Sq == 1
    k: jnp.ndarray,  # (B, Skv, Hkv, Dh)
    v: jnp.ndarray,
    *,
    q_pos: jnp.ndarray,  # (Sq,) shared, or (B, Sq) per-slot
    kv_pos: jnp.ndarray,  # (Skv,) shared, or (B, Skv) per-slot
    causal: bool,
    window: Optional[int],
) -> jnp.ndarray:
    b, sq, h, dh = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qh = q.reshape(b, sq, hkv, g, dh).astype(jnp.float32) * (dh ** -0.5)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qh, k.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    # 2-D positions carry a per-batch (slot) row: each sequence in the batch
    # masks against its own cache occupancy (continuous batching decode).
    qp = q_pos if q_pos.ndim == 2 else q_pos[None, :]  # (1 | B, Sq)
    kp = kv_pos if kv_pos.ndim == 2 else kv_pos[None, :]  # (1 | B, Skv)
    valid = kp[:, None, :] >= 0
    if causal:
        valid &= kp[:, None, :] <= qp[:, :, None]
    if window is not None:
        valid &= kp[:, None, :] > qp[:, :, None] - window
    s = jnp.where(valid[:, None, None], s, mask_value(s.dtype))
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dh).astype(q.dtype)


def attention_apply(
    p: dict,
    x: jnp.ndarray,  # (B, S, D)
    cfg: ArchConfig,
    *,
    positions: jnp.ndarray,  # (S,) int32 absolute positions of x
    causal: bool = True,
    window: Optional[int] = None,
    use_rope: bool = True,
    ctx: Optional[jnp.ndarray] = None,  # cross-attn context (B, P, Dv)
    cache: Optional[dict] = None,
    cache_index: Optional[jnp.ndarray] = None,  # scalar int32 write offset
    block_tables: Optional[jnp.ndarray] = None,  # (B, n_blocks) physical ids
    attend_cache: bool = False,  # prefill: attend over the (prefix) cache
    paged: Optional[str] = None,  # fused paged decode kernel impl
    q_lens: Optional[jnp.ndarray] = None,  # (B,) valid tokens per row (mixed)
):
    """Returns (out (B,S,D), new_cache_or_None).

    ``block_tables`` switches the decode path to block-table indirection:
    the cache leaves are a physical-block arena ((n_blocks, block_size,
    ...)) and row r's K/V is gathered through ``block_tables[r]`` — two
    rows pointing at the same physical block share that KV (prefix
    caching). ``paged`` selects the fused paged-attention decode instead of
    materializing that gather (``"pallas"`` / ``"pallas_interpret"`` /
    ``"xla"``, see :mod:`repro.kernels.paged_attention`); ``None`` keeps
    the einsum-over-gather reference path. ``attend_cache`` makes a
    multi-token prefill attend over the *updated cache* instead of just its
    own K/V, which is what lets a prefill chunk see everything committed
    before it — a cached prompt prefix, previously prefilled chunks, or
    both; the kv_pos >= 0 masking contract is unchanged in all modes.

    ``q_lens`` (with ``block_tables``) selects the fused mixed-step path:
    row ``r`` carries ``q_lens[r]`` real tokens starting at its own
    ``cache_index[r]`` (decode rows 1, chunk rows up to S, idle rows 0),
    every row's valid K/V is scatter-committed into the arena through its
    block table inside this same launch, and attention reads the arena
    through the tables — one dispatch covers the decode batch and a
    prefill chunk with zero host-side commit work afterwards.
    """
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    b, s, _ = x.shape

    q = _split_heads(dense(p["wq"], x, cfg), h, dh)
    kv_src = ctx if ctx is not None else x
    k = _split_heads(dense(p["wk"], kv_src, cfg), hkv, dh)
    v = _split_heads(dense(p["wv"], kv_src, cfg), hkv, dh)

    if use_rope and ctx is None:
        pos_b = positions if positions.ndim == 2 else positions[None, :]
        q = rope(q, pos_b, cfg.rope_theta)
        k = rope(k, pos_b, cfg.rope_theta)

    new_cache = None
    if ctx is not None:
        kv_pos = jnp.arange(ctx.shape[1], dtype=jnp.int32)
        out = chunked_attention(
            q, k, v, q_pos=positions, kv_pos=kv_pos, causal=False,
            window=None, chunk=cfg.attn_chunk,
        )
    elif cache is not None:
        # Ring-buffer cache {'k','v','pos'} of length cache_len (== window
        # for local attention). The position plane is either shared across
        # the batch (1-D, static engine) or per-slot (2-D (B, cache_len),
        # continuous batching), and ``cache_index`` is either a scalar
        # (lockstep batch) or a (B,) vector (per-slot decode positions).
        # Statically-distinguished write modes: full-sequence prefill, tail
        # prefill (S >= cache_len), lockstep single-token decode, and
        # per-slot single-token decode (each row wraps at its own slot).
        idx = cache_index
        cache_len = cache["k"].shape[1]
        per_slot = cache["pos"].ndim == 2
        kd = k.astype(cache["k"].dtype)
        vd = v.astype(cache["v"].dtype)
        new_pos = positions.astype(jnp.int32)
        if q_lens is not None and block_tables is not None:
            # fused mixed step: decode rows (1 valid token) and a prefill
            # chunk's rows (up to S valid tokens) share one launch. Every
            # row's valid tokens are written straight into the arena
            # through its block table — invalid tokens (mixed-batch
            # padding, idle decode rows) are routed to the trash block 0,
            # so the commit needs no host-side scatter afterwards — and
            # attention reads each row's K/V through its table, paged or
            # gathered. Valid writes cannot collide: a request's write
            # region lies in blocks it exclusively owns, and each request
            # contributes valid tokens from exactly one row.
            #
            # Speculative decode rides this same path for BOTH of its
            # launches (never the plain block-table decode branch below,
            # whose bi is unclipped): draft steps are S=1 rows with
            # q_lens in {0, 1} (rows past their per-row draft budget mask
            # to the trash block), and the verify launch feeds S = k+1
            # tokens per speculating row. Rejected drafts need no explicit
            # rollback: their stale arena entries sit at positions strictly
            # beyond every later query position until the next feed window
            # overwrites them (write-before-attend in this same block), so
            # causal masking (kv pos <= q pos) keeps them unread.
            assert jnp.ndim(idx) == 1 and per_slot, (jnp.ndim(idx), per_slot)
            nb = block_tables.shape[1]
            pos2 = positions  # (B, S): row r writes at idx[r] + [0, S)
            tok_valid = (jnp.arange(s, dtype=jnp.int32)[None, :]
                         < q_lens[:, None])  # (B, S)
            bi = jnp.clip(pos2 // cache_len, 0, nb - 1)
            phys = jnp.where(tok_valid,
                             jnp.take_along_axis(block_tables, bi, axis=1),
                             0)  # (B, S); invalid tokens -> trash block
            off = jnp.mod(pos2, cache_len)
            fp, fo = phys.reshape(-1), off.reshape(-1)
            ck = cache["k"].at[fp, fo].set(
                kd.reshape((b * s,) + kd.shape[2:]))
            cv = cache["v"].at[fp, fo].set(
                vd.reshape((b * s,) + vd.shape[2:]))
            cp = cache["pos"].at[fp, fo].set(
                jnp.where(tok_valid, pos2, -1).reshape(-1))
            if paged is not None:
                out = paged_attention_decode(
                    q, ck, cv, cp, block_tables, pos2[:, 0],
                    q_lens=q_lens, causal=causal, window=window, impl=paged)
            else:
                gk = ck[block_tables].reshape(
                    (b, nb * cache_len) + ck.shape[2:])
                gv = cv[block_tables].reshape(
                    (b, nb * cache_len) + cv.shape[2:])
                gp = jnp.where((block_tables == 0)[:, :, None], -1,
                               cp[block_tables]).reshape(b, nb * cache_len)
                out = chunked_attention(
                    q, gk, gv, q_pos=pos2, kv_pos=gp, causal=causal,
                    window=window, chunk=cfg.attn_chunk)
            y = dense(p["wo"], out.reshape(b, s, h * dh), cfg)
            return y, {"k": ck, "v": cv, "pos": cp}
        if jnp.ndim(idx) == 1 and block_tables is not None:
            # block-table decode: the cache is a physical-block arena; row
            # r's token lands in block idx[r] // bs at offset idx[r] % bs
            # of whatever physical block its table maps it to. Attention
            # then gathers the row's K/V *through the table*, so physical
            # blocks shared between rows (cached prefixes) are read in
            # place — zero copies, zero recompute.
            assert s == 1 and per_slot, (s, per_slot)
            nb = block_tables.shape[1]
            bi = idx // cache_len  # logical block of each row's write
            off = jnp.mod(idx, cache_len)
            phys = jnp.take_along_axis(block_tables, bi[:, None],
                                       axis=1)[:, 0]  # (B,)
            ck = cache["k"].at[phys, off].set(kd[:, 0])
            cv = cache["v"].at[phys, off].set(vd[:, 0])
            cp = cache["pos"].at[phys, off].set(new_pos[:, 0])
            if paged is not None:
                # fused path: the kernel indexes the arena through the
                # table in place — the gathered K/V below never exists
                out = paged_attention_decode(
                    q, ck, cv, cp, block_tables, positions[:, 0],
                    causal=causal, window=window, impl=paged)
            else:
                gk = ck[block_tables].reshape(
                    (b, nb * cache_len) + ck.shape[2:])
                gv = cv[block_tables].reshape(
                    (b, nb * cache_len) + cv.shape[2:])
                # logical blocks mapped to the trash block (id 0:
                # unallocated table tails, free slots) are invalid by
                # definition — their positions must never enter the mask,
                # whatever garbage the free-slot dummy writes left in
                # block 0's pos plane
                gp = jnp.where((block_tables == 0)[:, :, None], -1,
                               cp[block_tables]).reshape(b, nb * cache_len)
                out = full_attention(q, gk, gv, q_pos=positions, kv_pos=gp,
                                     causal=causal, window=window)
            y = dense(p["wo"], out.reshape(b, s, h * dh), cfg)
            return y, {"k": ck, "v": cv, "pos": cp}
        if jnp.ndim(idx) == 1:
            # per-slot decode: row r writes token at its own position idx[r]
            assert s == 1 and per_slot, (s, per_slot)
            slot = jnp.mod(idx, cache_len)  # (B,)
            ck = jax.vmap(
                lambda c, u, sl: jax.lax.dynamic_update_slice(c, u, (sl, 0, 0))
            )(cache["k"], kd, slot)
            cv = jax.vmap(
                lambda c, u, sl: jax.lax.dynamic_update_slice(c, u, (sl, 0, 0))
            )(cache["v"], vd, slot)
            cp = jax.vmap(
                lambda c, u, sl: jax.lax.dynamic_update_slice(c, u, (sl,))
            )(cache["pos"], new_pos, slot)
        elif s >= cache_len:
            # Keep the ring invariant slot == pos % cache_len so later
            # single-token writes overwrite the *oldest* entry.
            shift = jnp.mod(new_pos[-cache_len], cache_len)
            ck = jnp.roll(kd[:, -cache_len:], shift, axis=1)
            cv = jnp.roll(vd[:, -cache_len:], shift, axis=1)
            cp = jnp.roll(new_pos[-cache_len:], shift)
            if per_slot:
                cp = jnp.broadcast_to(cp[None, :], (b, cache_len))
        elif s == 1:
            slot = jnp.mod(idx, cache_len)
            ck = jax.lax.dynamic_update_slice(cache["k"], kd, (0, slot, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], vd, (0, slot, 0, 0))
            if per_slot:
                cp = jax.lax.dynamic_update_slice(
                    cache["pos"], jnp.broadcast_to(new_pos[None, :], (b, 1)),
                    (0, slot))
            else:
                cp = jax.lax.dynamic_update_slice(cache["pos"], new_pos,
                                                  (slot,))
        else:  # chunked prefill within capacity (no wrap by construction)
            ck = jax.lax.dynamic_update_slice(cache["k"], kd, (0, idx, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], vd, (0, idx, 0, 0))
            if per_slot:
                cp = jax.lax.dynamic_update_slice(
                    cache["pos"], jnp.broadcast_to(new_pos[None, :], (b, s)),
                    (0, idx))
            else:
                cp = jax.lax.dynamic_update_slice(cache["pos"], new_pos,
                                                  (idx,))
        new_cache = {"k": ck, "v": cv, "pos": cp}
        if s == 1:
            # decode: attend over the (ring) cache
            out = full_attention(q, ck, cv, q_pos=positions, kv_pos=cp,
                                 causal=causal, window=window)
        elif attend_cache and s < cache_len:
            # chunk / suffix prefill past a committed position: the cache
            # rows [0, cache_index) hold valid K/V (cached prefix and/or
            # earlier chunks) and this chunk was just written at
            # [cache_index, cache_index + s), so the chunk's queries
            # attend over the whole updated cache (invalid entries are
            # pos == -1 and masked as always).
            out = chunked_attention(
                q, ck, cv, q_pos=positions, kv_pos=cp, causal=causal,
                window=window, chunk=cfg.attn_chunk)
        else:
            # whole-prompt prefill: the ring cache only retains the last
            # `cache_len` KVs, so early queries must attend over the full
            # current K/V (cache is write-only here; decode reads it).
            out = chunked_attention(
                q, k, v, q_pos=positions, kv_pos=positions, causal=causal,
                window=window, chunk=cfg.attn_chunk)
    else:
        kv_pos = positions
        out = chunked_attention(
            q, k, v, q_pos=positions, kv_pos=kv_pos, causal=causal,
            window=window, chunk=cfg.attn_chunk,
        )

    y = dense(p["wo"], out.reshape(b, s, h * dh), cfg)
    return y, new_cache
