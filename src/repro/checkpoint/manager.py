"""Fault-tolerant checkpointing.

* **Atomic**: leaves are written to ``step_XXXX.tmp/`` then the directory is
  renamed — a crash mid-write never corrupts the latest checkpoint.
* **Sharding-agnostic**: every leaf is gathered to a full host array
  (``.npy``), so a restore can use a *different* mesh / device count than the
  save (elastic re-scaling); the restore path re-shards via the caller's
  shardings.
* **Retention**: keeps the newest ``keep`` checkpoints.
* **Async**: ``save(..., blocking=False)`` hands the host arrays to a writer
  thread so the train loop overlaps checkpoint I/O with compute.
* **Manifest**: step, data-pipeline cursor, RNG state, and the flattened key
  paths — enough to resume bit-exactly.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _unflatten_into(template, flat: Dict[str, np.ndarray]):
    paths = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        leaves.append(flat[key])
    return jax.tree_util.tree_unflatten(paths[1], leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._q: "queue.Queue" = queue.Queue()
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}")

    def all_steps(self):
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------------

    def _write(self, step: int, flat: Dict[str, np.ndarray], meta: dict):
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for key, arr in flat.items():
            fn = key.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, fn), arr)
        meta = dict(meta)
        meta["step"] = step
        meta["keys"] = sorted(flat.keys())
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    def save(self, step: int, tree, meta: Optional[dict] = None,
             blocking: bool = True):
        flat = _flatten(tree)  # host gather happens on the caller thread
        meta = meta or {}
        if blocking:
            self._write(step, flat, meta)
        else:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, flat, meta), daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ------------------------------------------------------------------

    def restore(self, template, step: Optional[int] = None,
                shardings=None):
        """Restore into the structure of ``template``.

        ``shardings``: optional tree of NamedSharding congruent with
        ``template`` — leaves are re-sharded onto the current mesh (elastic
        restore after a topology change).
        """
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            meta = json.load(f)
        flat = {}
        for key in meta["keys"]:
            fn = key.replace("/", "__") + ".npy"
            flat[key] = np.load(os.path.join(d, fn))
        tree = _unflatten_into(template, flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda arr, sh: jax.device_put(arr, sh), tree, shardings)
        return tree, meta
