"""Conv-layer shapes of the paper's benchmark networks.

(name, C_in, C_out, kernel, stride, H_in, W_in, depthwise)
Only convolutional layers — the paper evaluates conv layers only (§5).
"""
from __future__ import annotations

import dataclasses
from typing import List


@dataclasses.dataclass(frozen=True)
class ConvLayer:
    name: str
    c_in: int
    c_out: int
    k: int
    stride: int
    h: int
    w: int
    depthwise: bool = False

    @property
    def out_h(self) -> int:
        return self.h // self.stride

    @property
    def out_w(self) -> int:
        return self.w // self.stride

    @property
    def macs(self) -> int:
        ch = self.c_in if not self.depthwise else 1
        return self.out_h * self.out_w * self.k * self.k * ch * self.c_out

    @property
    def weight_count(self) -> int:
        ch = self.c_in if not self.depthwise else 1
        return self.k * self.k * ch * self.c_out

    @property
    def act_in_count(self) -> int:
        return self.h * self.w * self.c_in

    @property
    def act_out_count(self) -> int:
        return self.out_h * self.out_w * self.c_out


def _resnet18() -> List[ConvLayer]:
    ls = [ConvLayer("conv1", 3, 64, 7, 2, 224, 224)]
    cfg = [(64, 64, 56, 2), (64, 128, 56, 2), (128, 256, 28, 2),
           (256, 512, 14, 2)]
    h = 56
    cin = 64
    for i, (ci, co, hh, nblocks) in enumerate(cfg):
        for b in range(nblocks):
            stride = 2 if (b == 0 and i > 0) else 1
            hin = hh if b == 0 else hh // (2 if i > 0 else 1)
            ls.append(ConvLayer(f"l{i}b{b}c1", cin, co, 3, stride, hin, hin))
            ls.append(ConvLayer(f"l{i}b{b}c2", co, co, 3, 1, hin // stride,
                                hin // stride))
            cin = co
    return ls


def _mobilenet_v2() -> List[ConvLayer]:
    # (t expand, c_out, n blocks, stride), input 224
    cfg = [(1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
           (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1)]
    ls = [ConvLayer("conv1", 3, 32, 3, 2, 224, 224)]
    cin, h = 32, 112
    for i, (t, c, n, s) in enumerate(cfg):
        for b in range(n):
            stride = s if b == 0 else 1
            hid = cin * t
            if t != 1:
                ls.append(ConvLayer(f"b{i}_{b}_pw1", cin, hid, 1, 1, h, h))
            ls.append(ConvLayer(f"b{i}_{b}_dw", hid, hid, 3, stride, h, h,
                                depthwise=True))
            h = h // stride
            ls.append(ConvLayer(f"b{i}_{b}_pw2", hid, c, 1, 1, h, h))
            cin = c
    ls.append(ConvLayer("conv_last", 320, 1280, 1, 1, 7, 7))
    return ls


def _vgg16_cifar() -> List[ConvLayer]:
    cfg = [(3, 64), (64, 64), (64, 128), (128, 128), (128, 256), (256, 256),
           (256, 256), (256, 512), (512, 512), (512, 512), (512, 512),
           (512, 512), (512, 512)]
    hs = [32, 32, 16, 16, 8, 8, 8, 4, 4, 4, 2, 2, 2]
    return [ConvLayer(f"conv{i}", ci, co, 3, 1, h, h)
            for i, ((ci, co), h) in enumerate(zip(cfg, hs))]


NETWORKS = {
    "resnet18": _resnet18(),
    "mobilenet_v2": _mobilenet_v2(),
    "vgg16_cifar": _vgg16_cifar(),
}
