"""Paper-faithful analytical performance model: bit-serial systolic array
(SCALE-Sim-like OS dataflow) with the paper's 28nm PE synthesis constants."""
from repro.perfmodel.pe import PEConfig, PE_LIBRARY
from repro.perfmodel.systolic import SystolicArray, LayerShape, simulate_layer, simulate_network
from repro.perfmodel.networks import NETWORKS

__all__ = ["PEConfig", "PE_LIBRARY", "SystolicArray", "LayerShape",
           "simulate_layer", "simulate_network", "NETWORKS"]
