"""Processing-element area/energy models (paper §3.1, Fig. 3; Table 4 anchors).

The paper synthesizes 8-bit fixed-point, single-shift (SS) and double-shift
(DS) bit-serial PEs at group sizes 2-16 in 28nm TSMC. We calibrate an
analytical PE model against the paper's own Table 4 (ResNet-18 column):

* "8-b FX" baseline = conventional 8x8 systolic array (ONE 8-bit MAC per PE
  per cycle — group applies to the bit-serial PEs).
* Bit-serial PEs process a G=4 depth-wise group per shift pass; SWIS needs
  ceil(N / shifts_per_cycle) passes (N = effective shifts).
* BitFusion 4x8 = decomposable array, 2x MAC lanes at 4-bit weights, with a
  fusion-network energy overhead.

Calibration (grid-fit to 10 Table-4 ResNet-18 points, see EXPERIMENTS.md):
  clock 650 MHz; MAC8 = 0.15 pJ; bit-serial pass = 0.34 * MAC8 (DS pass
  1.15x); fixed per-MAC buffering overhead 0.08 * MAC8; SRAM 1.1 pJ/B;
  DRAM 24 pJ/B (LPDDR4-class, with OS-dataflow weight re-fetch); BitFusion energy overhead 1.6x.
Fit quality: F/s within 5% on all 10 points; F/J within 7% on the SWIS
family; the act-trunc / wgt-trunc / fixed8 / BitFusion baselines come out
15-60% MORE energy-efficient than the paper reports, i.e. our reproduced
speedup/energy ratios are CONSERVATIVE w.r.t. the paper's claims.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

CLOCK_HZ = 0.65e9
MAC8_PJ = 0.15  # 8-bit fixed-point MAC energy, pJ (28nm, calibrated)
SRAM_PJ_PER_BYTE = 1.1
DRAM_PJ_PER_BYTE = 24.0
PASS_RATIO = 0.34  # bit-serial shift-pass energy / MAC8 (per group-MAC)
PASS_OVERHEAD = 0.08  # activation buffering etc., x MAC8 per MAC
DS_PASS_FACTOR = 1.15  # double-shift pass costs 1.3x an SS pass (does 2 shifts)
BITFUSION_E_OVERHEAD = 1.6
FIXED_PE_AREA_MM2 = 0.0042  # one 8-bit MAC lane incl. local buffers


@dataclasses.dataclass(frozen=True)
class PEConfig:
    """One PE variant of the paper's design space."""

    name: str
    style: str  # 'fixed' | 'bitserial'
    shifts_per_cycle: int = 1  # 1 = SS, 2 = DS
    group: int = 4  # MAC lanes (bit-serial: weights sharing a shift pass)
    energy_overhead: float = 1.0

    def area_ratio(self) -> float:
        """Fig. 3a: a group-G bit-serial PE ~ area of G/4 fixed MAC lanes
        (paper arrays are all ~0.54-0.55 mm^2 at G=4)."""
        if self.style == "fixed":
            return 1.0 * self.group
        base = self.group / 4.0
        if self.shifts_per_cycle == 2:
            base *= 1.04  # DS adds a second shifter path (0.55 vs 0.54 mm^2)
        return base

    def area_mm2(self) -> float:
        return FIXED_PE_AREA_MM2 * self.area_ratio()

    def cycles_per_mac_group(self, n_shifts: float) -> float:
        """Cycles to retire one group of MACs."""
        if self.style == "fixed":
            return 1.0
        return max(math.ceil(n_shifts / self.shifts_per_cycle), 1)

    def energy_per_mac_pj(self, n_shifts: float) -> float:
        """Energy per equivalent 8-bit MAC (Fig. 3b shape, Table 4 calib)."""
        if self.style == "fixed":
            return MAC8_PJ * self.energy_overhead
        per_pass = MAC8_PJ * PASS_RATIO
        if self.shifts_per_cycle == 2:
            per_pass *= DS_PASS_FACTOR
        passes = max(math.ceil(n_shifts / self.shifts_per_cycle), 1)
        return per_pass * passes + MAC8_PJ * PASS_OVERHEAD

    def macs_per_cycle(self, n_shifts: float, depthwise: bool = False) -> float:
        g = 1 if (depthwise and self.style == "bitserial") else self.group
        return g / self.cycles_per_mac_group(n_shifts)


PE_LIBRARY: Dict[str, PEConfig] = {
    # conventional 8-bit array: 1 MAC/PE/cycle
    "fixed8": PEConfig("fixed8", "fixed", 1, 1),
    "swis_ss": PEConfig("swis_ss", "bitserial", 1, 4),
    "swis_ds": PEConfig("swis_ds", "bitserial", 2, 4),
    # SWIS-C shares the PE; only shift decode differs
    "swis_c_ss": PEConfig("swis_c_ss", "bitserial", 1, 4),
    "swis_c_ds": PEConfig("swis_c_ds", "bitserial", 2, 4),
    # Stripes-like activation-serial; weights parallel (8b), acts serial
    "act_trunc": PEConfig("act_trunc", "bitserial", 1, 4),
    "wgt_trunc": PEConfig("wgt_trunc", "bitserial", 1, 4),
    # BitFusion: 2x lanes at 4-bit weights + fusion-network overhead
    "bitfusion_4x8": PEConfig("bitfusion_4x8", "fixed", 1, 2,
                              energy_overhead=BITFUSION_E_OVERHEAD),
}
