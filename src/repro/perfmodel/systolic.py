"""Output-stationary systolic-array simulator (SCALE-Sim-style analytical
model, paper §3.2 / §5) for bit-serial SWIS execution.

Array: R x C PEs, each PE processes a depth-wise group of G weights per
cycle (G MACs/cycle for fixed point; G per shift pass for bit-serial).
OS dataflow mapping for a conv layer lowered to GEMM
(M = out pixels, N = out channels, K = k*k*C_in):

  spatial tiles: M over rows (R), N over columns (C), K in groups of G
  cycles(tile)  = K/G * passes + (R + C) pipeline fill
  passes        = ceil(n_shifts / shifts_per_cycle)   (1 for fixed point)

SRAM traffic: OS keeps the output stationary; each (R x C) tile streams its
activations and weights once per K-pass. Weight DRAM traffic is divided by
the SWIS compression ratio (the paper's §3.3 bandwidth saving); activations
are read/written once per layer (+ re-reads when the weight working set
exceeds the weight SRAM).

Depthwise convolutions under-utilize the group dimension (G_eff = 1),
matching the paper's MobileNet discussion.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List

from repro.core.packing import compression_ratio
from repro.perfmodel.networks import ConvLayer
from repro.perfmodel.pe import (CLOCK_HZ, DRAM_PJ_PER_BYTE, PEConfig,
                                SRAM_PJ_PER_BYTE)


@dataclasses.dataclass(frozen=True)
class SystolicArray:
    pe: PEConfig
    rows: int = 8
    cols: int = 8
    act_sram_kb: int = 64
    wgt_sram_kb: int = 64
    out_sram_kb: int = 16

    def area_mm2(self) -> float:
        return self.rows * self.cols * self.pe.area_mm2() + 0.27  # SRAM+NoC


@dataclasses.dataclass
class LayerShape:
    m: int  # output pixels
    n: int  # output channels
    k: int  # reduction (k*k*C_in)
    depthwise: bool = False
    ifmap_elems: int = 0  # true input feature map size (line-buffer reuse)
    ofmap_elems: int = 0

    def __post_init__(self):
        if not self.ifmap_elems:
            self.ifmap_elems = self.m * self.k
        if not self.ofmap_elems:
            self.ofmap_elems = self.m * self.n

    @classmethod
    def from_conv(cls, l: ConvLayer) -> "LayerShape":
        ch = l.c_in if not l.depthwise else 1
        return cls(m=l.out_h * l.out_w, n=l.c_out, k=l.k * l.k * ch,
                   depthwise=l.depthwise, ifmap_elems=l.act_in_count,
                   ofmap_elems=l.act_out_count)


def _weight_bits_per_element(method: str, n_shifts: float, group: int) -> float:
    if method == "fixed8":
        return 8.0
    if method == "act_trunc":
        return 8.0  # weights stay 8-bit; activations are truncated
    if method == "wgt_trunc":
        return max(n_shifts, 1.0) + 1.0  # N-bit weights + sign
    if method == "bitfusion":
        return 4.0
    variant = "swis_c" if method.startswith("swis_c") else "swis"
    return 8.0 / compression_ratio(group, int(round(n_shifts)), variant)


def simulate_layer(arr: SystolicArray, shape: LayerShape, *,
                   n_shifts: float, method: str) -> Dict[str, float]:
    """Cycle + energy model for one GEMM-lowered layer."""
    pe = arr.pe
    g_eff = 1 if (shape.depthwise and pe.style == "bitserial") else pe.group
    # serial passes over shift planes (weight-serial SWIS / weight trunc),
    # or over activation bits (activation truncation — same cycle count)
    if pe.style == "fixed":
        passes = 1
    else:
        passes = max(math.ceil(n_shifts / pe.shifts_per_cycle), 1)

    m_tiles = math.ceil(shape.m / arr.rows)
    n_tiles = math.ceil(shape.n / arr.cols)
    k_steps = math.ceil(shape.k / g_eff)
    fill = arr.rows + arr.cols  # pipeline fill/drain per tile
    cycles = m_tiles * n_tiles * (k_steps * passes + fill)

    macs = shape.m * shape.n * shape.k
    e_mac = pe.energy_per_mac_pj(n_shifts if pe.style != "fixed" else 8)
    if shape.depthwise and pe.style == "bitserial":
        # group under-utilization: energy still paid for the full group
        e_mac = e_mac * pe.group

    # --- SRAM traffic (bytes) ---
    act_reads = shape.m * shape.k * n_tiles  # ifmap streamed per col tile
    act_bits = 8.0
    wgt_bits = _weight_bits_per_element(method, n_shifts, pe.group)
    wgt_reads_elems = shape.k * shape.n * m_tiles
    out_writes = shape.m * shape.n
    sram_bytes = (act_reads * act_bits + wgt_reads_elems * wgt_bits) / 8.0 \
        + out_writes * 2  # 16-bit partial-sum writeback

    # --- DRAM traffic (bytes) ---
    # Weights are RE-STREAMED once per output-row tile when the footprint
    # exceeds the weight SRAM (OS dataflow; this is the paper's Fig.-1
    # "weights dominate DRAM accesses" effect, which SWIS compression
    # divides directly). Activations get line-buffer reuse (ifmap read once,
    # ofmap written once).
    wgt_footprint = shape.k * shape.n * wgt_bits / 8.0
    wgt_sram_bytes = arr.wgt_sram_kb * 1024
    refetch = m_tiles if wgt_footprint > wgt_sram_bytes else 1
    wgt_bytes_dram = wgt_footprint * refetch
    act_bytes_dram = (shape.ifmap_elems + shape.ofmap_elems) * act_bits / 8.0
    dram_bytes = wgt_bytes_dram + act_bytes_dram

    energy_pj = (macs * e_mac + sram_bytes * SRAM_PJ_PER_BYTE
                 + dram_bytes * DRAM_PJ_PER_BYTE)
    return {
        "cycles": float(cycles),
        "macs": float(macs),
        "energy_pj": energy_pj,
        "dram_bytes": dram_bytes,
        "wgt_dram_bytes": wgt_bytes_dram,
        "act_dram_bytes": act_bytes_dram,
        "sram_bytes": sram_bytes,
    }


def simulate_network(arr: SystolicArray, layers: List[ConvLayer], *,
                     n_shifts: float, method: str) -> Dict[str, float]:
    tot: Dict[str, float] = {}
    for layer in layers:
        r = simulate_layer(arr, LayerShape.from_conv(layer),
                           n_shifts=n_shifts, method=method)
        for k, v in r.items():
            tot[k] = tot.get(k, 0.0) + v
    secs = tot["cycles"] / CLOCK_HZ
    joules = tot["energy_pj"] * 1e-12
    tot["frames_per_s"] = 1.0 / secs
    tot["frames_per_j"] = 1.0 / joules
    return tot
