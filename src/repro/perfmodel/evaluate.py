"""Table-4 reproduction: F/J and F/s for every accelerator configuration at
the paper's iso-accuracy shift counts, plus Fig. 1 (DRAM W/A access ratio)
and speedup/energy headline ratios.

Accuracy-matched shift counts come straight from the paper's Table 4 rows
("S" columns): e.g. ResNet-18 @ >69.1%: SWIS-SS 3, SWIS-DS 4, SWIS-C-SS 4,
SWIS-C-DS 4, act-trunc 7, wgt-trunc 6.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.perfmodel.networks import NETWORKS
from repro.perfmodel.pe import PE_LIBRARY
from repro.perfmodel.systolic import SystolicArray, simulate_network

# (config, shift counts per accuracy point) — paper Table 4 "S" columns.
TABLE4_POINTS = {
    "resnet18": {
        "hi": {"swis_ss": 3, "swis_ds": 4, "swis_c_ss": 4, "swis_c_ds": 4,
               "act_trunc": 7, "wgt_trunc": 6, "bitfusion_4x8": 4,
               "fixed8": 8},
        "lo": {"swis_ss": 2, "swis_ds": 2, "swis_c_ss": 2, "swis_c_ds": 2,
               "act_trunc": 6, "wgt_trunc": 4, "fixed8": 8},
    },
    "mobilenet_v2": {
        "hi": {"swis_ss": 5, "swis_ds": 5, "swis_c_ss": 5, "swis_c_ds": 6,
               "act_trunc": 7, "wgt_trunc": 6, "fixed8": 8},
        "lo": {"swis_ss": 3.5, "swis_ds": 4, "swis_c_ss": 4, "swis_c_ds": 4,
               "act_trunc": 6, "wgt_trunc": 5, "fixed8": 8},
    },
    "vgg16_cifar": {
        "hi": {"swis_ss": 3, "swis_ds": 4, "swis_c_ss": 4, "swis_c_ds": 4,
               "act_trunc": 7, "wgt_trunc": 6, "bitfusion_4x8": 4,
               "fixed8": 8},
        "lo": {"swis_ss": 2.5, "swis_ds": 2.5, "swis_c_ss": 3,
               "swis_c_ds": 3, "act_trunc": 6, "wgt_trunc": 4, "fixed8": 8},
    },
}

_METHOD_FOR = {
    "swis_ss": "swis", "swis_ds": "swis",
    "swis_c_ss": "swis_c", "swis_c_ds": "swis_c",
    "act_trunc": "act_trunc", "wgt_trunc": "wgt_trunc",
    "bitfusion_4x8": "bitfusion", "fixed8": "fixed8",
}


def evaluate_table4(rows: int = 8, cols: int = 8) -> List[Dict]:
    out = []
    for net, points in TABLE4_POINTS.items():
        layers = NETWORKS[net]
        for point, cfgs in points.items():
            for cfg_name, n_shifts in cfgs.items():
                arr = SystolicArray(PE_LIBRARY[cfg_name], rows, cols)
                r = simulate_network(arr, layers, n_shifts=n_shifts,
                                     method=_METHOD_FOR[cfg_name])
                out.append({
                    "network": net, "point": point, "config": cfg_name,
                    "n_shifts": n_shifts,
                    "frames_per_s": r["frames_per_s"],
                    "frames_per_j": r["frames_per_j"],
                    "area_mm2": arr.area_mm2(),
                    "dram_bytes": r["dram_bytes"],
                })
    return out


def headline_ratios(rows: int = 8, cols: int = 8) -> Dict[str, float]:
    """The paper's claims: up to 6x speedup / 1.9x energy vs act-trunc
    bit-serial; weight DRAM bandwidth reduction vs fixed8."""
    table = evaluate_table4(rows, cols)

    def get(net, point, cfg):
        for r in table:
            if (r["network"], r["point"], r["config"]) == (net, point, cfg):
                return r
        raise KeyError((net, point, cfg))

    speedups, energies = [], []
    for net in TABLE4_POINTS:
        for point in ("hi", "lo"):
            at = get(net, point, "act_trunc")
            for cfg in ("swis_ss", "swis_ds"):
                sw = get(net, point, cfg)
                speedups.append(sw["frames_per_s"] / at["frames_per_s"])
                energies.append(sw["frames_per_j"] / at["frames_per_j"])
    fx = get("resnet18", "hi", "fixed8")
    sw = get("resnet18", "lo", "swis_c_ss")
    return {
        "max_speedup_vs_act_trunc": max(speedups),
        "min_speedup_vs_act_trunc": min(speedups),
        "max_energy_ratio_vs_act_trunc": max(energies),
        "dram_reduction_vs_fixed8": fx["dram_bytes"] / sw["dram_bytes"],
    }


def fig1_dram_ratio() -> List[Tuple[str, float]]:
    """Fig. 1: per-layer DRAM weight/activation access ratio, ResNet-18."""
    from repro.perfmodel.systolic import LayerShape, simulate_layer

    arr = SystolicArray(PE_LIBRARY["fixed8"])
    out = []
    for layer in NETWORKS["resnet18"]:
        r = simulate_layer(arr, LayerShape.from_conv(layer), n_shifts=8,
                           method="fixed8")
        out.append((layer.name,
                    r["wgt_dram_bytes"] / max(r["act_dram_bytes"], 1)))
    return out
