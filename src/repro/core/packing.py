"""SWIS compressed weight storage (paper §3.3) — TPU lane-tiled bit-planes.

Per group of ``M`` weights (along K) the format stores:

* 1 sign bit / weight            -> ``sign_plane``  uint32 (K/32, C)
* N mask bits / weight           -> ``mask_planes`` uint32 (N, K/32, C)
* N shift values of 3 bits each  -> ``shifts``      int8   (K/M, C, N)
  (SWIS-C stores a single 3-bit offset per group -> (K/M, C, 1) + N)
* per-column scale               -> ``scale``       float32 (1, C)

Bits are packed along K, 32 weights per uint32 word, so a (block_k, block_n)
tile of the dense weight matrix corresponds to contiguous
(block_k/32, block_n) words of each plane — the layout the Pallas kernel
streams HBM->VMEM.

Compression ratios (vs B-bit baseline, ignoring the shared scale):
  SWIS:   B*M / (M*(1+N) + 3*N)
  SWIS-C: B*M / (M*(1+N) + 3)
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.swis import QuantizedWeight


def pack_bits_u32(bits: jnp.ndarray) -> jnp.ndarray:
    """Pack a {0,1} array (K, ...) along axis 0 into uint32 (K/32, ...)."""
    k = bits.shape[0]
    if k % 32:
        raise ValueError(f"K={k} not divisible by 32")
    r = bits.reshape(k // 32, 32, *bits.shape[1:]).astype(jnp.uint32)
    w = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)).reshape(
        (1, 32) + (1,) * (bits.ndim - 1)
    )
    return jnp.sum(r * w, axis=1, dtype=jnp.uint32)


def unpack_bits_u32(words: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`pack_bits_u32` -> int32 {0,1} of shape (K, ...)."""
    kw = words.shape[0]
    idx = jnp.arange(32, dtype=jnp.uint32).reshape((1, 32) + (1,) * (words.ndim - 1))
    bits = (words[:, None] >> idx) & jnp.uint32(1)
    return bits.reshape(kw * 32, *words.shape[1:]).astype(jnp.int32)


def pack_shift_nibbles(shifts: jnp.ndarray) -> jnp.ndarray:
    """Pack 3-bit shift values two-per-byte: (..., N) int -> (..., ceil(N/2))
    uint8 (low nibble = even index). Keeps HBM shift traffic at 4 bits per
    shift instead of 8 (the paper's accounting is 3; 4 aligns to nibbles)."""
    n = shifts.shape[-1]
    s = shifts.astype(jnp.uint8)
    if n % 2:
        s = jnp.concatenate([s, jnp.zeros(s.shape[:-1] + (1,), jnp.uint8)],
                            axis=-1)
    lo = s[..., 0::2]
    hi = s[..., 1::2]
    return lo | (hi << 4)


def unpack_shift_nibbles(packed: jnp.ndarray, n_shifts: int) -> jnp.ndarray:
    """Inverse of :func:`pack_shift_nibbles` -> (..., n_shifts) int32."""
    lo = (packed & jnp.uint8(0x0F)).astype(jnp.int32)
    hi = ((packed >> 4) & jnp.uint8(0x0F)).astype(jnp.int32)
    out = jnp.stack([lo, hi], axis=-1).reshape(packed.shape[:-1] + (-1,))
    return out[..., :n_shifts]


@dataclasses.dataclass
class PackedWeight:
    """SWIS bit-plane weight container (pytree-compatible via .tree())."""

    sign_plane: jnp.ndarray  # uint32 (K/32, C); bit=1 => negative
    mask_planes: jnp.ndarray  # uint32 (N, K/32, C)
    shifts: jnp.ndarray  # uint8 (K/M, C, ceil(N/2)) nibble-packed
    scale: jnp.ndarray  # float32 (1, C) or scalar
    group_size: int
    n_shifts: int
    k: int
    c: int
    method: str = "swis"

    def tree(self) -> dict:
        return {
            "sign_plane": self.sign_plane,
            "mask_planes": self.mask_planes,
            "shifts": self.shifts,
            "scale": self.scale,
        }

    @property
    def stored_bits(self) -> int:
        """Exact metadata-true storage in bits (paper §3.3 accounting)."""
        n_groups = (self.k // self.group_size) * self.c
        mask_bits = self.k * self.c * self.n_shifts
        sign_bits = self.k * self.c
        shift_bits = n_groups * (3 if self.method == "swis_c" else 3 * self.n_shifts)
        return mask_bits + sign_bits + shift_bits

    @property
    def compression_ratio(self) -> float:
        return (self.k * self.c * 8) / self.stored_bits


def pack(qw: QuantizedWeight) -> PackedWeight:
    """Pack a :class:`QuantizedWeight` into bit planes.

    Columns quantized with fewer shifts than the max simply have all-zero
    high mask planes (the scheduling §4.3 guarantee that co-scheduled
    columns share a shift count is enforced at tile granularity by
    :mod:`repro.core.scheduling`).
    """
    k, c = qw.qmags.shape
    n = int(qw.shifts.shape[-1])
    m = qw.cfg.group_size
    if k % 32:
        raise ValueError(f"K={k} must be a multiple of 32 to pack")
    sign_bits = (qw.signs < 0).astype(jnp.uint32)
    planes = []
    for j in range(n):
        planes.append(pack_bits_u32((qw.masks >> j) & 1))
    if qw.cfg.method == "swis_c":
        # consecutive support vector: store ONLY the per-group offset
        # (paper §2.2 — the SWIS-C compression advantage); shift j = off + j
        shift_store = qw.shifts[..., :1].astype(jnp.uint8)
    else:
        shift_store = pack_shift_nibbles(qw.shifts)
    return PackedWeight(
        sign_plane=pack_bits_u32(sign_bits),
        mask_planes=jnp.stack(planes),
        shifts=shift_store,
        scale=jnp.asarray(qw.scale, jnp.float32),
        group_size=m,
        n_shifts=n,
        k=k,
        c=c,
        method=qw.cfg.method,
    )


def unpack_dense(pw: PackedWeight, dtype=jnp.float32) -> jnp.ndarray:
    """Reconstruct the dense dequantized (K, C) matrix from planes."""
    sign = 1.0 - 2.0 * unpack_bits_u32(pw.sign_plane).astype(jnp.float32)
    if pw.method == "swis_c":
        shifts = pw.shifts[..., :1].astype(jnp.int32) + jnp.arange(
            pw.n_shifts, dtype=jnp.int32)
    else:
        shifts = unpack_shift_nibbles(pw.shifts, pw.n_shifts)
    acc = jnp.zeros((pw.k, pw.c), jnp.float32)
    for j in range(pw.n_shifts):
        bits = unpack_bits_u32(pw.mask_planes[j]).astype(jnp.float32)
        s = shifts[:, :, j].astype(jnp.float32)  # (K/M, C)
        s_full = jnp.repeat(s, pw.group_size, axis=0)  # (K, C)
        acc = acc + bits * jnp.exp2(s_full)
    return (sign * acc * pw.scale).astype(dtype)


# ---------------------------------------------------------------------------
# Compression math (Fig. 5) + DPRed comparison baseline.
# ---------------------------------------------------------------------------


def compression_ratio(group_size: int, n_shifts: int, method: str = "swis",
                      bits: int = 8) -> float:
    m, n = group_size, n_shifts
    shift_bits = 3 if method == "swis_c" else 3 * n
    return bits * m / (m * (1 + n) + shift_bits)


def dpred_compression(mags: np.ndarray, group_size: int, bits: int = 8) -> float:
    """DPRed-style lossless per-group bitwidth compression (paper Fig. 5).

    Each group stores its weights with the bitwidth of the highest active
    bit position in the group, plus sign bits and a ceil(log2(B+1))-bit
    per-group width field.
    """
    k = mags.shape[0]
    m = group_size
    if k % m:
        mags = mags[: k - k % m]
    g = mags.reshape(-1, m, *mags.shape[1:])
    gmax = g.max(axis=1)
    width = np.ceil(np.log2(np.maximum(gmax, 1) + 1)).astype(np.int64)
    width = np.maximum(width, 1)
    n_groups = width.size
    total = (width * m).sum() + n_groups * int(np.ceil(np.log2(bits + 1))) + g.size
    return g.size * bits / float(total)
