"""SWIS core: quantization, selection, scheduling, packing (the paper's
primary contribution, in JAX)."""
from repro.core.swis import QuantConfig, QuantizedWeight, quantize, fake_quant, act_truncate, rmse
from repro.core.packing import PackedWeight, pack, unpack_dense, compression_ratio
from repro.core.qat import ste_quant, maybe_quant
from repro.core import probability, selection, scheduling

__all__ = [
    "QuantConfig", "QuantizedWeight", "quantize", "fake_quant", "act_truncate",
    "rmse", "PackedWeight", "pack", "unpack_dense", "compression_ratio",
    "ste_quant", "maybe_quant", "probability", "selection", "scheduling",
]
