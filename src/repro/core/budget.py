"""Cross-layer shift-budget allocation (beyond-paper extension of §4.3).

The paper schedules shift counts across *filters within one layer*. The same
marginal-cost greedy extends across *layers*: under a global parameter-
weighted average-shift budget, layers that are cheap to demote (low weight-
space MSE++ increase per saved bit) give up shifts so sensitive layers keep
them. This is the knapsack-greedy on marginal returns:

  1. profile: for every eligible GEMM weight, weight-space MSE++ at each
     candidate shift count (scale^2 folds the int-domain cost back to
     weight space so layers are comparable);
  2. allocate: start every tensor at max(levels); repeatedly demote the
     tensor with the smallest  d(cost) / d(bits saved)  until the
     parameter-weighted average hits the target;
  3. apply: per-tensor QuantConfig overrides (PTQ or QAT).

Used by ``benchmarks/paper_tables.py::beyond_budget`` which shows the
allocated network beating uniform allocation at iso-budget.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.swis import QuantConfig, _column_costs, _to_int_domain, fake_quant


_NAMES = ("w", "wi", "wo", "wg", "shared_wi", "shared_wo", "shared_wg")


def _budget_eligible(path, arr) -> bool:
    # fake-quant pads K, so (unlike bit-plane packing) no K%32 constraint
    if len(arr.shape) < 2 or str(path[-1]) not in _NAMES:
        return False
    joined = "/".join(str(p) for p in path)
    return not ("embed" in joined or "router" in joined
                or "frontend" in joined)


def _eligible_leaves(params) -> List[Tuple[Tuple[str, ...], jnp.ndarray]]:
    out = []

    def walk(path, node):
        if isinstance(node, dict):
            for k, v in node.items():
                walk(path + (k,), v)
            return
        if _budget_eligible(path, node):
            out.append((path, node))

    walk((), params)
    return out


def sensitivity_profile(
    params,
    qcfg: QuantConfig,
    levels: Sequence[int] = (1, 2, 3, 4, 5),
) -> Dict[Tuple, Dict[int, float]]:
    """Weight-space MSE++ at each shift count, per allocation unit.

    Stacked leaves (scan-over-layers: (L, K, C) / (L, E, K, C)) are
    unstacked so every layer (and expert) gets its own unit — the
    cross-layer analogue of the paper's per-filter granularity.
    """
    profile: Dict[Tuple, Dict[int, float]] = {}
    for path, w in _eligible_leaves(params):
        w = jnp.asarray(w, jnp.float32)
        units = ([(path, w)] if w.ndim == 2 else
                 [(path + (i,), w.reshape(-1, *w.shape[-2:])[i])
                  for i in range(int(np.prod(w.shape[:-2])))])
        for upath, w2 in units:
            k = w2.shape[0]
            if k % qcfg.group_size:
                pad = (-k) % qcfg.group_size
                w2 = jnp.pad(w2, ((0, pad), (0, 0)))
            mags, signs, scale = _to_int_domain(w2, qcfg.bits,
                                                qcfg.per_channel)
            costs = {}
            for n in levels:
                _, col_cost = _column_costs(mags, signs, n, qcfg)
                costs[n] = float(jnp.sum(col_cost)) * float(
                    jnp.mean(scale)) ** 2
            profile[upath] = costs
    return profile


@dataclasses.dataclass
class BudgetAllocation:
    shifts: Dict[Tuple[str, ...], int]
    effective_shifts: float
    total_cost: float


def allocate(
    profile: Dict[Tuple[str, ...], Dict[int, float]],
    sizes: Dict[Tuple[str, ...], int],
    target_avg: float,
    levels: Sequence[int] = (1, 2, 3, 4, 5),
) -> BudgetAllocation:
    """Greedy marginal-cost demotion to a parameter-weighted average."""
    levels = sorted(levels)
    hi = levels[-1]
    cur = {p: hi for p in profile}
    total_params = sum(sizes[p] for p in profile)
    budget_bits = target_avg * total_params

    def bits(assign):
        return sum(assign[p] * sizes[p] for p in profile)

    # heap of (marginal cost per saved bit, path)
    def push(heap, p):
        n = cur[p]
        idx = levels.index(n)
        if idx == 0:
            return
        lo = levels[idx - 1]
        d_cost = profile[p][lo] - profile[p][n]
        d_bits = (n - lo) * sizes[p]
        heapq.heappush(heap, (d_cost / max(d_bits, 1), p, n))

    heap: list = []
    for p in profile:
        push(heap, p)
    while bits(cur) > budget_bits and heap:
        _, p, n_at_push = heapq.heappop(heap)
        if cur[p] != n_at_push:
            continue  # stale entry
        idx = levels.index(cur[p])
        if idx == 0:
            continue
        lo = levels[idx - 1]
        # no-overshoot: accept a budget-crossing demotion only if it lands
        # closer to the target than staying put
        before = bits(cur)
        after = before - (cur[p] - lo) * sizes[p]
        if after < budget_bits and (budget_bits - after) >= (before - budget_bits):
            continue
        cur[p] = lo
        push(heap, p)

    total_cost = sum(profile[p][cur[p]] for p in profile)
    eff = bits(cur) / total_params
    return BudgetAllocation(shifts=cur, effective_shifts=eff,
                            total_cost=total_cost)


def quantize_with_allocation(params, qcfg: QuantConfig,
                             alloc: BudgetAllocation):
    """PTQ the tree with per-unit shift counts from an allocation."""

    def walk(path, node):
        if isinstance(node, dict):
            return {k: walk(path + (k,), v) for k, v in node.items()}
        if not _budget_eligible(path, node):
            return node
        if node.ndim == 2:
            if path not in alloc.shifts:
                return node
            return fake_quant(node, dataclasses.replace(
                qcfg, n_shifts=alloc.shifts[path]))
        lead = node.shape[:-2]
        flat = node.reshape(-1, *node.shape[-2:])
        slices = []
        for i in range(flat.shape[0]):
            n = alloc.shifts.get(path + (i,))
            slices.append(flat[i] if n is None else fake_quant(
                flat[i], dataclasses.replace(qcfg, n_shifts=n)))
        return jnp.stack(slices).reshape(lead + node.shape[-2:])

    return walk((), params)


def leaf_sizes(params) -> Dict[Tuple, int]:
    sizes: Dict[Tuple, int] = {}
    for path, w in _eligible_leaves(params):
        if w.ndim == 2:
            sizes[path] = int(np.prod(w.shape))
        else:
            unit = int(np.prod(w.shape[-2:]))
            for i in range(int(np.prod(w.shape[:-2]))):
                sizes[path + (i,)] = unit
    return sizes
