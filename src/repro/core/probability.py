"""Closed-form lossless-quantization probabilities (paper Eqs. 8-10, Fig. 2).

Probability that a uniformly random ``B``-bit integer is representable
exactly ("losslessly") by each quantization family using ``N`` shifts:

* SWIS (Eq. 8):        any sparse subset of N bit positions.
* SWIS-C (Eq. 9):      a consecutive window of N bit positions.
* layer-wise (Eq. 10): a single fixed subset of N positions.
"""
from __future__ import annotations

import math


def _comb(n: int, k: int) -> int:
    if k < 0 or n < 0 or k > n:
        return 0
    return math.comb(n, k)


def p_lossless_swis(n_shifts: int, bits: int = 8) -> float:
    """Eq. 8: P = sum_{n=0}^{N} C(B, n) * 0.5^B."""
    return sum(_comb(bits, n) for n in range(n_shifts + 1)) * 0.5 ** bits


def p_lossless_swis_c(n_shifts: int, bits: int = 8) -> float:
    """Eq. 9.

    For each popcount n <= N the fraction of bit patterns whose active bits
    fit inside *some* consecutive window of length N is
    ``(C(N, n) * (B - N + 1) - (B - N) * C(N - 1, n)) / C(B, n)``
    (windows overlap; the subtracted term removes double counting of
    patterns fitting in two adjacent windows, via inclusion-exclusion on
    patterns fitting in a window of length N-1).
    """
    N = n_shifts
    if N == 0:
        # Eq. 9 assumes N >= 1; with no shifts only the value 0 is exact.
        return 0.5 ** bits
    total = 0.0
    for n in range(N + 1):
        numer = _comb(N, n) * (bits - N + 1) - (bits - N) * _comb(N - 1, n)
        total += numer * 0.5 ** bits
    return total


def p_lossless_layerwise(n_shifts: int, bits: int = 8) -> float:
    """Eq. 10: the N active positions are fixed for the whole layer."""
    N = n_shifts
    total = 0.0
    for n in range(N + 1):
        total += _comb(N, n) * 0.5 ** bits
    return total


def lossless_table(bits: int = 8) -> dict[str, list[float]]:
    """Fig. 2 data: probability for every N in [0, bits]."""
    ns = range(bits + 1)
    return {
        "n_shifts": list(ns),
        "swis": [p_lossless_swis(n, bits) for n in ns],
        "swis_c": [p_lossless_swis_c(n, bits) for n in ns],
        "layerwise": [p_lossless_layerwise(n, bits) for n in ns],
    }
