"""SWIS filter scheduling (paper §4.3) — exact offline scheduler.

Two phases, faithful to the paper:

1. **Greedy demotion.** All filters (output columns) start one level above
   the target. Repeatedly compute the MSE++ cost *increase* of demoting each
   filter by one shift, demote the ``n_demote`` cheapest, recompute, until
   the layer-average number of shifts equals the target.

2. **Systolic-group snapping.** Filters sorted by assigned shift count are
   partitioned into groups of ``sa_cols`` filters that the systolic array
   schedules simultaneously — all filters in a group must share a shift
   count. We enumerate nondecreasing per-group shift sequences that meet the
   layer-average budget and pick the sequence with the lowest total MSE++.

Runs offline in numpy (host); the output feeds :func:`repro.core.swis.quantize`
column assignments and the packer.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Sequence

import numpy as np


@dataclasses.dataclass
class Schedule:
    col_shifts: np.ndarray  # (C,) per-column shift counts (original order)
    order: np.ndarray  # (C,) column permutation (sorted by shifts)
    group_shifts: np.ndarray  # (n_groups,) shift count per systolic group
    total_cost: float
    effective_shifts: float


def _check_costs(costs: dict[int, np.ndarray]) -> Sequence[int]:
    levels = sorted(costs)
    c = len(next(iter(costs.values())))
    for n in levels:
        if len(costs[n]) != c:
            raise ValueError("cost arrays must share column count")
    return levels


def greedy_demotion(
    costs: dict[int, np.ndarray],
    target: float,
    *,
    n_demote: int = 1,
    step: int = 1,
) -> np.ndarray:
    """Phase 1: per-filter shift counts averaging to ``target``.

    ``costs[n][c]`` is the layer MSE++ of column ``c`` quantized with ``n``
    shifts. ``step`` is 2 for double-shift PEs (even counts only).
    """
    levels = _check_costs(costs)
    c = len(costs[levels[0]])
    hi = min(lv for lv in levels if lv >= target + (step - 1e-9)) if any(
        lv >= target + step - 1e-9 for lv in levels
    ) else max(levels)
    cur = np.full(c, hi, np.int64)
    lo = min(levels)
    total_budget = target * c
    demotions_needed = int(round((cur.sum() - total_budget) / step))
    for _ in range(max(demotions_needed, 0)):
        cand = cur - step >= lo
        if not cand.any():
            break
        penalty = np.where(
            cand,
            np.array([costs[max(n - step, lo)][i] - costs[n][i]
                      for i, n in enumerate(cur)]),
            np.inf,
        )
        order = np.argsort(penalty)
        for idx in order[:n_demote]:
            if cur[idx] - step >= lo and cur.sum() - step >= total_budget:
                cur[idx] -= step
    return cur


def snap_to_groups(
    col_shifts: np.ndarray,
    costs: dict[int, np.ndarray],
    target: float,
    *,
    sa_cols: int,
    step: int = 1,
) -> Schedule:
    """Phase 2: enforce a uniform shift count per systolic group.

    Sorts columns by phase-1 shift count, then enumerates nondecreasing
    per-group sequences with the required average and picks the cheapest.
    """
    levels = sorted(costs)
    c = len(col_shifts)
    if c % sa_cols:
        raise ValueError(f"column count {c} not divisible by sa_cols {sa_cols}")
    n_groups = c // sa_cols
    order = np.argsort(col_shifts, kind="stable")
    budget = target * c

    # All nondecreasing sequences over `levels` of length n_groups whose
    # group-weighted sum equals the budget.
    best_seq, best_cost = None, np.inf
    for seq in itertools.combinations_with_replacement(levels, n_groups):
        if abs(sum(seq) * sa_cols - budget) > 1e-6:
            continue
        cost = 0.0
        for g, n in enumerate(seq):
            cols = order[g * sa_cols : (g + 1) * sa_cols]
            cost += costs[n][cols].sum()
        if cost < best_cost:
            best_cost, best_seq = cost, seq

    if best_seq is None:
        # Fall back to the uniform ceiling level (target not representable).
        lvl = min((lv for lv in levels if lv >= target),
                  default=max(levels))
        best_seq = tuple([lvl] * n_groups)
        best_cost = sum(costs[lvl][order].sum() for _ in range(1)) * 1.0

    out = np.zeros(c, np.int64)
    for g, n in enumerate(best_seq):
        out[order[g * sa_cols : (g + 1) * sa_cols]] = n
    return Schedule(
        col_shifts=out,
        order=order,
        group_shifts=np.asarray(best_seq, np.int64),
        total_cost=float(best_cost),
        effective_shifts=float(out.mean()),
    )


def schedule_layer(
    cost_fn: Callable[[int], np.ndarray],
    target: float,
    *,
    levels: Sequence[int],
    sa_cols: int = 8,
    double_shift: bool = False,
    n_demote: int = 1,
) -> Schedule:
    """End-to-end §4.3 scheduling for one layer.

    ``cost_fn(n)`` returns per-column MSE++ at shift count ``n``.
    """
    step = 2 if double_shift else 1
    if double_shift:
        levels = [lv for lv in levels if lv % 2 == 0]
    costs = {n: np.asarray(cost_fn(n), np.float64) for n in levels}
    phase1 = greedy_demotion(costs, target, n_demote=n_demote, step=step)
    return snap_to_groups(phase1, costs, target, sa_cols=sa_cols, step=step)
