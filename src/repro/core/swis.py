"""High-level SWIS weight quantization API (paper §2, §4).

Entry points:

* :func:`quantize`     — full post-training quantization of a weight matrix,
                         returning dequantized weights + all metadata needed
                         for packing (signs / masks / shifts / scales).
* :func:`fake_quant`   — jit-friendly dequantize-only path (used for QAT and
                         for quantization-in-the-loss-graph). Supports
                         fractional effective shift targets via in-graph
                         filter scheduling (paper §4.3, simplified: global
                         top-k column assignment).
* :func:`act_truncate` — the activation-truncation baseline of Stripes-like
                         accelerators (paper §5: layer-wise LSB truncation of
                         8-bit activations).

Weight layout convention: 2-D ``(K, C)`` with K the reduction (input) dim —
groups of ``group_size`` weights are taken along K per output column C,
matching the paper's depth-wise grouping (§3.2).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core import selection


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Configuration for SWIS quantization of one weight family.

    method: 'none' | 'swis' | 'swis_c' | 'trunc' (layer-wise weight
        truncation baseline). 'trunc_act' is handled at the activation side.
    n_shifts: effective number of shifts; fractional values engage filter
        scheduling (§4.3).
    group_size: PE group size M (weights sharing a support vector).
    alpha: MSE++ signed-error coefficient (Eq. 12).
    bits: underlying integer precision B.
    per_channel: per-output-column scales (True) or per-tensor (False).
    double_shift: restrict per-column shift counts to even values (DS PE,
        §3.1); fractional/odd targets are met by mixing even counts.
    schedule: enable in-graph filter scheduling for fractional targets.
    """

    method: str = "swis"
    n_shifts: float = 4
    group_size: int = 4
    alpha: float = 1.0
    bits: int = 8
    # Paper-faithful default: one scale per layer. Per-channel scales are a
    # beyond-paper accuracy option (see EXPERIMENTS.md §Perf).
    per_channel: bool = False
    double_shift: bool = False
    schedule: bool = True
    # Paper's weight-truncation baseline drops LSBs in hardware => floor.
    # round_trunc=True upgrades it to round-to-nearest (beyond-paper).
    round_trunc: bool = False

    @property
    def variant(self) -> str:
        return {"swis": "swis", "swis_c": "swis_c", "trunc": "trunc"}[self.method]

    def shift_levels(self) -> tuple[int, int, float]:
        """(n_lo, n_hi, fraction_of_columns_at_hi) realizing ``n_shifts``."""
        t = float(self.n_shifts)
        step = 2 if self.double_shift else 1
        lo = int(t // step) * step
        if lo == t and lo > 0:
            return lo, lo, 0.0
        lo = max(lo, 0)
        hi = lo + step
        if lo == 0:
            return hi, hi, 0.0  # below one step: round up
        return lo, hi, (t - lo) / step


def _to_int_domain(w: jnp.ndarray, bits: int, per_channel: bool):
    """Symmetric sign-magnitude quantization to B bits (Eq. 2 domain)."""
    maxq = float(2 ** bits - 1)
    absw = jnp.abs(w)
    amax = jnp.max(absw, axis=0, keepdims=True) if per_channel else jnp.max(absw)
    scale = jnp.maximum(amax / maxq, 1e-12)
    mags = jnp.clip(jnp.round(absw / scale), 0.0, maxq)
    signs = jnp.where(w < 0, -1.0, 1.0)
    return mags.astype(jnp.float32), signs.astype(jnp.float32), scale


def _column_costs(mags, signs, n, cfg: QuantConfig, chunk_elems=None):
    kw = {}
    if chunk_elems is not None:
        kw["chunk_elems"] = chunk_elems
    out = selection.quantize_grouped(
        mags,
        signs,
        n_shifts=n,
        group_size=cfg.group_size,
        bits=cfg.bits,
        variant=cfg.variant,
        alpha=cfg.alpha,
        **kw,
    )
    return out, out["cost"].sum(axis=0)  # (C,) summed MSE++ per column


# In-graph (QAT) path: never chunk — under SPMD the lax.map scan would slice
# along a sharded axis and force all-gathers; sharding already bounds the
# per-device working set. The offline PTQ path keeps the default chunking.
_NO_CHUNK = 1 << 62


def _floor_truncate(mags: jnp.ndarray, n: int, bits: int) -> jnp.ndarray:
    """Hardware LSB truncation: drop the lowest (bits - n) magnitude bits."""
    step = float(2 ** (bits - int(n)))
    return jnp.floor(mags / step) * step


@functools.partial(jax.jit, static_argnames=("cfg",))
def _fake_quant_impl(w: jnp.ndarray, cfg: QuantConfig) -> jnp.ndarray:
    mags, signs, scale = _to_int_domain(w, cfg.bits, cfg.per_channel)
    n_lo, n_hi, frac = cfg.shift_levels()

    if cfg.method == "trunc" and not cfg.round_trunc:
        q = _floor_truncate(mags, max(n_lo, 1), cfg.bits)
        return (signs * q * scale).astype(w.dtype)

    if n_lo == n_hi or not cfg.schedule or frac == 0.0:
        out, _ = _column_costs(mags, signs, n_hi if n_lo != n_hi else n_lo,
                               cfg, chunk_elems=_NO_CHUNK)
        q = out["qmags"]
    else:
        out_lo, cost_lo = _column_costs(mags, signs, n_lo, cfg,
                                        chunk_elems=_NO_CHUNK)
        out_hi, cost_hi = _column_costs(mags, signs, n_hi, cfg,
                                        chunk_elems=_NO_CHUNK)
        # §4.3 (in-graph form): columns with the largest penalty for being
        # demoted keep the higher shift count; the assignment keeps the
        # layer-average number of shifts equal to the target. frac and the
        # column count are trace-time constants, so k_hi is static.
        penalty = cost_lo - cost_hi  # >= 0
        c = mags.shape[1]
        k_hi = int(round(frac * c))
        _, top_idx = jax.lax.top_k(penalty, max(k_hi, 1))
        use_hi = jnp.zeros((c,), bool).at[top_idx[:k_hi]].set(True)
        q = jnp.where(use_hi[None, :], out_hi["qmags"], out_lo["qmags"])
    return (signs * q * scale).astype(w.dtype)


def fake_quant(w: jnp.ndarray, cfg: QuantConfig) -> jnp.ndarray:
    """Quantize-dequantize ``w`` under ``cfg`` (no packing). Jit-friendly.

    Accepts any array whose *leading* axis is the reduction dim; trailing
    axes are flattened into columns.
    """
    if cfg.method == "none":
        return w
    shape = w.shape
    k = shape[0]
    w2 = w.reshape(k, -1)
    m = cfg.group_size
    if k % m:
        pad = (-k) % m
        w2 = jnp.pad(w2, ((0, pad), (0, 0)))
        q = _fake_quant_impl(w2, cfg)[:k]
    else:
        q = _fake_quant_impl(w2, cfg)
    return q.reshape(shape)


@dataclasses.dataclass
class QuantizedWeight:
    """Full PTQ result for one (K, C) weight matrix."""

    qweights: jnp.ndarray  # (K, C) dequantized float
    qmags: jnp.ndarray  # (K, C) integer-valued magnitudes
    signs: jnp.ndarray  # (K, C) {-1, +1}
    masks: jnp.ndarray  # (K, C) int32 mask-bit pattern per weight
    shifts: jnp.ndarray  # (K//M, C, N) int32 selected bit positions
    scale: jnp.ndarray  # (1, C) or scalar
    col_shifts: jnp.ndarray  # (C,) int32 per-column shift count
    cost: jnp.ndarray  # (K//M, C) group MSE++
    cfg: QuantConfig


def quantize(w: jnp.ndarray, cfg: QuantConfig) -> QuantizedWeight:
    """Post-training SWIS quantization with metadata (not jitted; offline)."""
    if w.ndim != 2:
        raise ValueError("quantize expects a 2-D (K, C) matrix; reshape first")
    K, C = w.shape
    if K % cfg.group_size:
        raise ValueError(f"K={K} not divisible by group size {cfg.group_size}")
    mags, signs, scale = _to_int_domain(w, cfg.bits, cfg.per_channel)
    n_lo, n_hi, frac = cfg.shift_levels()

    if cfg.method == "trunc" and not cfg.round_trunc:
        n = max(n_lo, 1)
        qm = _floor_truncate(mags, n, cfg.bits)
        window = jnp.arange(cfg.bits - n, cfg.bits, dtype=jnp.int32)
        masks = (qm / float(2 ** (cfg.bits - n))).astype(jnp.int32)
        shifts = jnp.broadcast_to(
            window, (K // cfg.group_size, C, n)).astype(jnp.int32)
        err = mags - qm
        cost = (err ** 2).reshape(K // cfg.group_size, cfg.group_size, C).sum(1)
        return QuantizedWeight(
            qweights=(signs * qm * scale).astype(w.dtype),
            qmags=qm, signs=signs, masks=masks, shifts=shifts, scale=scale,
            col_shifts=jnp.full((C,), n, jnp.int32), cost=cost, cfg=cfg,
        )

    if n_lo == n_hi or not cfg.schedule or frac == 0.0:
        n = n_hi if n_lo != n_hi else n_lo
        out, _ = _column_costs(mags, signs, n, cfg)
        col_shifts = jnp.full((C,), n, jnp.int32)
        qm, masks, shifts, cost = out["qmags"], out["masks"], out["shifts"], out["cost"]
    else:
        out_lo, cost_lo = _column_costs(mags, signs, n_lo, cfg)
        out_hi, cost_hi = _column_costs(mags, signs, n_hi, cfg)
        penalty = cost_lo - cost_hi
        k_hi = int(round(frac * C))
        order = jnp.argsort(-penalty)
        use_hi = jnp.zeros((C,), bool).at[order[:k_hi]].set(True)
        qm = jnp.where(use_hi[None, :], out_hi["qmags"], out_lo["qmags"])
        masks = jnp.where(use_hi[None, :], out_hi["masks"], out_lo["masks"])
        # Pad lo shifts with an inert extra position (repeat last) so shapes match.
        pad_n = out_hi["shifts"].shape[-1] - out_lo["shifts"].shape[-1]
        lo_shifts = jnp.concatenate(
            [out_lo["shifts"]] + [out_lo["shifts"][..., -1:]] * pad_n, axis=-1
        )
        shifts = jnp.where(use_hi[None, :, None], out_hi["shifts"], lo_shifts)
        cost = jnp.where(use_hi[None, :], out_hi["cost"], out_lo["cost"])
        col_shifts = jnp.where(use_hi, n_hi, n_lo).astype(jnp.int32)

    return QuantizedWeight(
        qweights=(signs * qm * scale).astype(w.dtype),
        qmags=qm,
        signs=signs,
        masks=masks,
        shifts=shifts,
        scale=scale,
        col_shifts=col_shifts,
        cost=cost,
        cfg=cfg,
    )


def rmse(w: jnp.ndarray, q: jnp.ndarray) -> jnp.ndarray:
    return jnp.sqrt(jnp.mean((w - q) ** 2))


@functools.partial(jax.jit, static_argnames=("n_shifts", "bits"))
def act_truncate(a: jnp.ndarray, n_shifts: int, bits: int = 8) -> jnp.ndarray:
    """Layer-wise activation LSB truncation baseline (paper §5).

    Quantizes activations to ``bits`` then zeroes the lowest ``bits-n`` bits.
    """
    maxq = float(2 ** bits - 1)
    amax = jnp.maximum(jnp.max(jnp.abs(a)), 1e-12)
    scale = amax / maxq
    mags = jnp.clip(jnp.round(jnp.abs(a) / scale), 0.0, maxq)
    step = float(2 ** (bits - n_shifts))
    mags = jnp.floor(mags / step) * step
    return (jnp.sign(a) * mags * scale).astype(a.dtype)
