"""Quantization-aware retraining support (paper §5.1.2).

Forward pass quantizes weights with SWIS (shift selection re-run per step,
"treated as a special quantization, updated per batch input"); the backward
pass is a straight-through estimator (STE) so gradients flow to the latent
full-precision weights.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.swis import QuantConfig, fake_quant


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def ste_quant(w: jnp.ndarray, cfg: QuantConfig) -> jnp.ndarray:
    """SWIS fake-quant with identity (straight-through) gradient."""
    return fake_quant(w, cfg)


def _fwd(w, cfg):
    return fake_quant(w, cfg), None


def _bwd(cfg, _res, g):
    return (g,)


ste_quant.defvjp(_fwd, _bwd)


def quantize_tree(params, qcfg: QuantConfig):
    """STE fake-quant every eligible GEMM weight leaf of a parameter tree.

    Used by the train step to quantize ONCE per optimizer step, *outside*
    the rematted per-layer scan and the grad-accumulation microbatch loop —
    selection then runs 1x per step instead of (2 x n_layers x n_micro)x
    (fwd + remat-bwd recompute). Semantics match the paper's "shift selection
    updated per batch input" (§5.1.2) exactly.
    """
    from repro.serve.quantized import _eligible

    def walk(path, node):
        if isinstance(node, dict):
            return {k: walk(path + (k,), v) for k, v in node.items()}
        if not _eligible(path, node):
            return node
        if node.ndim == 3:
            return jax.vmap(lambda m: ste_quant(m, qcfg))(node)
        return ste_quant(node, qcfg)

    return walk((), params)


def maybe_quant(w: jnp.ndarray, cfg: QuantConfig | None, mode: str) -> jnp.ndarray:
    """Uniform entry point used by model layers.

    mode: 'off' (no quant), 'qat' (STE fake-quant), 'ptq' (fake-quant, no
    gradient bypass — used for eval).
    """
    if cfg is None or cfg.method == "none" or mode == "off":
        return w
    if mode == "qat":
        return ste_quant(w, cfg)
    if mode == "ptq":
        return fake_quant(w, cfg)
    raise ValueError(f"unknown quant mode {mode!r}")
