"""SWIS shift selection (paper §4.1): per-group support-vector enumeration.

A *group* is ``M`` weights along the reduction (input-channel) dimension that
share a support vector of ``N`` bit positions out of ``B`` underlying bits.
For every candidate support vector we quantize each weight magnitude to the
nearest representable subset-sum and score the group with MSE++ (Eq. 12):

    MSE++ = (1/M) * ( alpha * (sum_i sign_i * (|w_i| - |q_i|))^2
                      + sum_i (|w_i| - |q_i|)^2 )

The enumeration is exact: C(B, N) combinations for SWIS, (B - N + 1)
consecutive windows for SWIS-C, and the single MSB window for layer-wise
truncation — all three run through the same machinery.
"""
from __future__ import annotations

import functools
from itertools import combinations

import jax
import jax.numpy as jnp
import numpy as np

VARIANTS = ("swis", "swis_c", "trunc")


@functools.lru_cache(maxsize=None)
def support_combos(n_shifts: int, bits: int = 8, variant: str = "swis") -> np.ndarray:
    """All candidate support vectors, shape (C, N), ascending bit positions."""
    if n_shifts <= 0 or n_shifts > bits:
        raise ValueError(f"n_shifts must be in [1, {bits}], got {n_shifts}")
    if variant == "swis":
        combos = list(combinations(range(bits), n_shifts))
    elif variant == "swis_c":
        combos = [tuple(range(o, o + n_shifts)) for o in range(bits - n_shifts + 1)]
    elif variant == "trunc":
        # layer-wise static: the fixed MSB window (LSB truncation).
        combos = [tuple(range(bits - n_shifts, bits))]
    else:
        raise ValueError(f"unknown variant {variant!r}")
    return np.asarray(combos, dtype=np.int32)


@functools.lru_cache(maxsize=None)
def combo_candidates(n_shifts: int, bits: int = 8, variant: str = "swis") -> np.ndarray:
    """Subset sums for every combo, shape (C, 2**N).

    Candidate ``k`` of combo ``c`` has value ``sum_j ((k >> j) & 1) * 2**s_cj``
    so the candidate index *is* the mask-bit pattern.
    """
    combos = support_combos(n_shifts, bits, variant)
    n = combos.shape[1]
    ks = np.arange(2 ** n, dtype=np.int64)
    sel = (ks[None, :, None] >> np.arange(n)[None, None, :]) & 1  # (1, K, N)
    vals = (sel * (2 ** combos.astype(np.int64))[:, None, :]).sum(-1)  # (C, K)
    return vals.astype(np.float32)


@functools.lru_cache(maxsize=None)
def _sorted_candidates(n_shifts: int, bits: int, variant: str):
    """Sorted candidate values + the mask index that produced each, per combo."""
    cand = combo_candidates(n_shifts, bits, variant)  # (C, K)
    order = np.argsort(cand, axis=1, kind="stable")
    return np.take_along_axis(cand, order, axis=1), order.astype(np.int32)


def _nearest_sorted(cand_sorted: jnp.ndarray, mags: jnp.ndarray):
    """Nearest value in a sorted 1-D candidate array for each magnitude.

    Returns (quantized values, index into the *sorted* array).
    """
    k = cand_sorted.shape[0]
    idx = jnp.searchsorted(cand_sorted, mags)
    idx = jnp.clip(idx, 1, k - 1)
    lo = cand_sorted[idx - 1]
    hi = cand_sorted[idx]
    take_lo = (mags - lo) <= (hi - mags)
    q = jnp.where(take_lo, lo, hi)
    j = jnp.where(take_lo, idx - 1, idx)
    return q, j


def _group_cost(mags, signs, q, alpha):
    """MSE++ over the last axis (the group axis), Eq. 12 (up to the 1/M factor,
    which does not change the argmin)."""
    err = mags - q
    signed = jnp.sum(signs * err, axis=-1)
    return alpha * signed * signed + jnp.sum(err * err, axis=-1)


@functools.partial(jax.jit, static_argnames=("n_shifts", "bits", "variant", "alpha"))
def select_shifts(
    mags: jnp.ndarray,
    signs: jnp.ndarray,
    *,
    n_shifts: int,
    bits: int = 8,
    variant: str = "swis",
    alpha: float = 1.0,
):
    """Exact enumeration over support vectors for grouped magnitudes.

    Args:
      mags:  (..., M) float32 integer-domain magnitudes in [0, 2**bits - 1].
             Arbitrary leading batch dims — they are preserved end-to-end
             (broadcasting only, no reshapes), so SPMD-sharded batch axes
             (e.g. the TP-sharded output-column axis) stay sharded and the
             selection induces NO collectives.
      signs: (..., M) float32 in {-1, +1}.

    Returns dict with (G = leading batch dims):
      qmags:  (..., M) quantized magnitudes (float32, integer-valued).
      shifts: (..., N) int32 selected bit positions (ascending).
      masks:  (..., M) int32 mask-bit pattern (bit j set => bit position
              shifts[..., j] active).
      combo:  (...) int32 index of the winning combo.
      cost:   (...) float32 winning MSE++ (without the 1/M factor).
    """
    cand_sorted_np, order_np = _sorted_candidates(n_shifts, bits, variant)
    combos_np = support_combos(n_shifts, bits, variant)
    cand_sorted = jnp.asarray(cand_sorted_np)  # (C, K)
    order = jnp.asarray(order_np)  # (C, K) sorted-pos -> mask index
    combos = jnp.asarray(combos_np)  # (C, N)

    def per_combo(cs):
        q, _ = _nearest_sorted(cs, mags)
        return _group_cost(mags, signs, q, alpha)

    costs = jax.vmap(per_combo)(cand_sorted)  # (C, ...)
    best = jnp.argmin(costs, axis=0)  # (...)
    best_cost = jnp.min(costs, axis=0)

    # Re-quantize against only the winning combo to recover masks. K = 2^N
    # is small, so an explicit distance argmin keeps everything batched.
    cs_best = cand_sorted[best]  # (..., K)
    d = jnp.abs(mags[..., None] - cs_best[..., None, :])  # (..., M, K)
    jpos = jnp.argmin(d, axis=-1)  # (..., M) position in sorted order
    qmags = jnp.take_along_axis(cs_best[..., None, :],
                                jpos[..., None], axis=-1)[..., 0]
    masks = jnp.take_along_axis(order[best][..., None, :],
                                jpos[..., None], axis=-1)[..., 0]

    return {
        "qmags": qmags,
        "shifts": combos[best],
        "masks": masks,
        "combo": best,
        "cost": best_cost,
    }


@functools.partial(jax.jit, static_argnames=("n_shifts", "bits", "variant", "alpha"))
def select_shifts_scan(
    mags: jnp.ndarray,
    signs: jnp.ndarray,
    *,
    n_shifts: int,
    bits: int = 8,
    variant: str = "swis",
    alpha: float = 1.0,
):
    """Running-min variant of :func:`select_shifts` (identical results).

    Scans over the (replicated) combo table carrying only the best-so-far
    tensors: peak memory drops ~C(B,N)x versus the vmap enumeration, and —
    because every op is an elementwise select or a searchsorted against a
    1-D replicated table — GSPMD keeps all batch axes sharded with ZERO
    collectives. This is the in-graph (QAT) selection path.
    """
    cand_sorted_np, order_np = _sorted_candidates(n_shifts, bits, variant)
    combos_np = support_combos(n_shifts, bits, variant)
    xs = (jnp.asarray(cand_sorted_np), jnp.asarray(order_np),
          jnp.asarray(combos_np))
    lead = mags.shape[:-1]
    m = mags.shape[-1]
    n = combos_np.shape[1]

    def step(carry, x):
        best_cost, q, masks, shifts = carry
        cs, order, combo = x  # (K,), (K,), (N,) — replicated tables
        qi, jpos = _nearest_sorted(cs, mags)
        cost = _group_cost(mags, signs, qi, alpha)
        better = cost < best_cost
        best_cost = jnp.where(better, cost, best_cost)
        q = jnp.where(better[..., None], qi, q)
        masks = jnp.where(better[..., None], order[jpos], masks)
        shifts = jnp.where(better[..., None], combo[(None,) * len(lead)],
                           shifts)
        return (best_cost, q, masks, shifts), None

    init = (jnp.full(lead, jnp.inf, jnp.float32),
            jnp.zeros(lead + (m,), jnp.float32),
            jnp.zeros(lead + (m,), jnp.int32),
            jnp.zeros(lead + (n,), jnp.int32))
    (best_cost, q, masks, shifts), _ = jax.lax.scan(step, init, xs)
    return {"qmags": q, "shifts": shifts, "masks": masks,
            "combo": None, "cost": best_cost}


def select_shifts_bruteforce(
    mags: np.ndarray,
    signs: np.ndarray,
    *,
    n_shifts: int,
    bits: int = 8,
    variant: str = "swis",
    alpha: float = 1.0,
):
    """Reference oracle: materializes every (combo, mask) pair. Small inputs only."""
    cand = combo_candidates(n_shifts, bits, variant)  # (C, K)
    G, M = mags.shape
    d = np.abs(mags[:, None, :, None] - cand[None, :, None, :])  # (G,C,M,K)
    kbest = np.argmin(d, axis=-1)  # (G,C,M)
    q = np.take_along_axis(
        np.broadcast_to(cand[None, :, None, :], d.shape), kbest[..., None], axis=-1
    )[..., 0]
    err = mags[:, None, :] - q
    signed = (signs[:, None, :] * err).sum(-1)
    cost = alpha * signed ** 2 + (err ** 2).sum(-1)  # (G, C)
    best = cost.argmin(axis=1)
    ar = np.arange(G)
    combos = support_combos(n_shifts, bits, variant)
    return {
        "qmags": q[ar, best],
        "shifts": combos[best],
        "masks": kbest[ar, best],
        "combo": best,
        "cost": cost[ar, best],
    }


def quantize_grouped(
    mags: jnp.ndarray,
    signs: jnp.ndarray,
    *,
    n_shifts: int,
    group_size: int,
    bits: int = 8,
    variant: str = "swis",
    alpha: float = 1.0,
    chunk_elems: int = 1 << 22,
):
    """Group a (K, C) magnitude matrix along K and run selection.

    Groups are formed depth-wise along the reduction axis (paper §3.2): group
    g of column c is ``mags[g*M:(g+1)*M, c]``.

    Sharding-aware layout: groups live as (K//M, C, M) — the (typically
    TP-sharded) column axis C is never merged into another dimension, so the
    whole selection runs shard-local under GSPMD (zero collectives). Memory
    is bounded by chunking along the K//M axis only.

    Returns dict of arrays shaped back to the matrix layout:
      qmags (K, C), shifts (K//M, C, N), masks (K, C), cost (K//M, C).
    """
    K, C = mags.shape
    M = group_size
    if K % M:
        raise ValueError(f"reduction dim {K} not divisible by group size {M}")
    kg = K // M
    # (K, C) -> (Kg, M, C) -> (Kg, C, M): pure split + transpose, C intact.
    g_mags = mags.reshape(kg, M, C).transpose(0, 2, 1)
    g_signs = signs.reshape(kg, M, C).transpose(0, 2, 1)

    sel = functools.partial(
        select_shifts_scan, n_shifts=n_shifts, bits=bits, variant=variant,
        alpha=alpha)
    chunk_kg = max(int(chunk_elems) // max(C * M, 1), 1)
    if kg <= chunk_kg:
        out = sel(g_mags, g_signs)
    else:
        pad = (-kg) % chunk_kg
        gm = jnp.pad(g_mags, ((0, pad), (0, 0), (0, 0)))
        gs = jnp.pad(g_signs, ((0, pad), (0, 0), (0, 0)), constant_values=1.0)
        gm = gm.reshape(-1, chunk_kg, C, M)
        gs = gs.reshape(-1, chunk_kg, C, M)
        out = jax.lax.map(lambda ab: sel(ab[0], ab[1]), (gm, gs))
        out = jax.tree.map(
            lambda x: x.reshape(-1, *x.shape[2:])[:kg], out)

    qm = out["qmags"].transpose(0, 2, 1).reshape(K, C)
    mk = out["masks"].transpose(0, 2, 1).reshape(K, C)
    return {
        "qmags": qm,
        "masks": mk,
        "shifts": out["shifts"],
        "combo": out["combo"],
        "cost": out["cost"],
    }
