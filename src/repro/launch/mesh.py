"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. The production target is a TPU v5e pod of 16x16 =
256 chips (axes data x model), and 2 pods = 512 chips with a leading 'pod'
axis for the multi-pod dry-run.
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants used by the roofline (per chip).
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (CPU tests)."""
    n = len(jax.devices())
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))
