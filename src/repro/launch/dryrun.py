import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes and record memory / cost / collective analyses.

MUST be run as its own process (the 512-device XLA flag above is set before
any other import, including jax). Results are cached as JSON per cell under
--out; re-runs skip completed cells, so the full sweep is resumable.

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-moe-a2.7b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.configs.base import ArchConfig, SHAPES, ShapeConfig, shape_applicable, QuantPolicy
from repro.core.swis import QuantConfig
from repro.launch import roofline as RL
from repro.launch.mesh import make_production_mesh
from repro.models import params as pp
from repro.models.model import Model
from repro.optim import AdamW
from repro.optim.schedule import warmup_cosine
from repro.parallel import ctx as par_ctx
from repro.parallel.sharding import Rules
from repro.serve.quantized import pack_placeholders
from repro.train.steps import TrainState, make_train_step


def _abstract_state(model: Model, rules: Rules) -> tuple[Any, Any]:
    tree = model.build()
    params = pp.abstract_params(tree)
    opt = {"m": pp.abstract_params(tree), "v": pp.abstract_params(tree)}
    state = TrainState(step=jax.ShapeDtypeStruct((), jnp.int32),
                       params=params, opt=opt)
    cfgp = model.cfg.parallel
    psh = rules.param_shardings(tree, fsdp=cfgp.fsdp_params)
    osh = {"m": rules.param_shardings(tree, fsdp=cfgp.fsdp_opt),
           "v": rules.param_shardings(tree, fsdp=cfgp.fsdp_opt)}
    sh = TrainState(step=rules.replicated(), params=psh, opt=osh)
    return state, sh


def _active_params(cfg: ArchConfig, tree) -> float:
    """Parameter count weighted by MoE activation fraction."""
    total = 0.0
    flat = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=pp.is_placeholder)[0]
    for path, leaf in flat:
        n = float(np.prod(leaf.shape))
        keys = "/".join(str(getattr(p, "key", p)) for p in path)
        if cfg.moe is not None and any(
                k in keys for k in ("/wi", "/wo", "/wg")) and "shared" not in keys \
                and "moe" in keys:
            n *= cfg.moe.top_k / cfg.moe.n_experts
        total += n
    return total


def _build_lowered(model_cfg: ArchConfig, shape: ShapeConfig, mesh, *,
                   quant: str, qcfg: QuantConfig):
    """Lower one step function for the given config (no compile)."""
    if shape.kind != "train" and model_cfg.parallel.sp:
        # measured (EXPERIMENTS.md §Perf): for fwd-only serving, plain TP
        # (one AR per block) beats SP (two AGs + RS) on wire bytes
        model_cfg = model_cfg.replace(parallel=dataclasses.replace(
            model_cfg.parallel, sp=False))
    model = Model(model_cfg)
    rules = Rules.for_arch(mesh, model_cfg)

    with par_ctx.use_rules(rules), mesh:
        if shape.kind == "train":
            gather_sh = None
            if model_cfg.parallel.fsdp_params:
                # pin the bf16 copy to the FSDP spec: the per-layer ZeRO-3
                # gather then happens inside the scan (bounded memory) but
                # provably on compute-dtype bytes (2x wire saving vs fp32)
                gather_sh = rules.param_shardings(model.build(), fsdp=True)
            step = make_train_step(model, AdamW(),
                                   warmup_cosine(1e-4, 100, 10000),
                                   compute_shardings=gather_sh)
            state, state_sh = _abstract_state(model, rules)
            batch = model.input_specs(shape)
            batch_sh = rules.batch_specs(batch)
            lowered = jax.jit(
                step, in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None), donate_argnums=(0,)
            ).lower(state, batch)
        elif shape.kind == "prefill":
            tree = model.build()
            if quant != "off":
                tree = pack_placeholders(tree, qcfg)
            # serving runs on compute-dtype params (bf16); packed leaves
            # keep their explicit uint32/int8 plane dtypes
            params = pp.abstract_params(tree, dtype=jnp.bfloat16)
            psh = rules.param_shardings(tree)
            batch = model.input_specs(shape)
            batch_sh = rules.batch_specs(batch)
            if model_cfg.family == "encoder":
                def fn(p, b):
                    return model.apply(p, b)[0]
                lowered = jax.jit(fn, in_shardings=(psh, batch_sh)
                                  ).lower(params, batch)
            else:
                ctree = model.build_cache(shape.global_batch, shape.seq_len,
                                          jnp.bfloat16)
                cache = pp.abstract_params(ctree)
                csh = rules.param_shardings(ctree)
                lowered = jax.jit(
                    model.prefill, in_shardings=(psh, batch_sh, csh),
                    donate_argnums=(2,)).lower(params, batch, cache)
        else:  # decode
            tree = model.build()
            if quant != "off":
                tree = pack_placeholders(tree, qcfg)
            params = pp.abstract_params(tree, dtype=jnp.bfloat16)
            psh = rules.param_shardings(tree)
            ctree = model.build_cache(shape.global_batch, shape.seq_len,
                                      jnp.bfloat16)
            cache = pp.abstract_params(ctree)
            csh = rules.param_shardings(ctree)
            tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
            idx = jax.ShapeDtypeStruct((), jnp.int32)

            if model_cfg.family == "vlm":
                patches = jax.ShapeDtypeStruct(
                    (shape.global_batch, model_cfg.vlm.n_patches,
                     model_cfg.vlm.vision_dim), jnp.bfloat16)

                def fn(p, t, c, i, pt):
                    logits, c2, _ = model.apply(
                        p, {"tokens": t, "patches": pt}, cache=c,
                        cache_index=i)
                    return logits[:, -1], c2

                lowered = jax.jit(
                    fn,
                    in_shardings=(psh, rules.batch_specs(tok), csh,
                                  rules.replicated(),
                                  rules.batch_specs(patches)),
                    donate_argnums=(2,),
                ).lower(params, tok, cache, idx, patches)
            else:
                lowered = jax.jit(
                    model.decode_step,
                    in_shardings=(psh, rules.batch_specs(tok), csh,
                                  rules.replicated()),
                    donate_argnums=(2,),
                ).lower(params, tok, cache, idx)

    return lowered


def _compiled_costs(compiled) -> Dict[str, float]:
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = RL.collective_bytes(hlo)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "collective_wire": float(coll["total"]),
        "collective_operand": float(coll["operand_total"]),
        "collectives": {k: v for k, v in coll.items()
                        if k in RL.COLLECTIVES},
        "collective_counts": coll["counts"],
    }


def _shallow_cfg(cfg: ArchConfig, k_units: int) -> ArchConfig:
    """Reduced-depth, unrolled, single-microbatch config for exact costing."""
    unit_len = len(Model(cfg).unit)
    tail = cfg.n_layers % unit_len
    return cfg.replace(
        n_layers=k_units * unit_len + tail,
        parallel=dataclasses.replace(cfg.parallel, scan_layers=False,
                                     grad_accum=1),
    )


def lower_cell(cfg: ArchConfig, shape: ShapeConfig, mesh, *,
               quant: str = "qat", qcfg: Optional[QuantConfig] = None,
               save_hlo: Optional[str] = None,
               depth_correct: bool = True) -> Dict[str, Any]:
    """Lower + compile one (arch x shape x mesh) cell; return the record.

    XLA's cost analysis counts a while-loop (lax.scan) body ONCE, so the
    full scanned compile (which proves sharding coherence + memory fit)
    undercounts flops/bytes/collectives. We therefore also compile the model
    at 1 and 2 pattern units (unrolled, exact) and extrapolate linearly:
    total = cost(1 unit + tail) + (n_units - 1) * [cost(2u) - cost(1u)].
    """
    qcfg = qcfg or QuantConfig(method="swis", n_shifts=4, group_size=4)
    model_cfg = cfg
    if shape.kind == "train":
        model_cfg = cfg.replace(
            quant=QuantPolicy(cfg=qcfg, mode="qat" if quant == "qat" else "off"))

    t0 = time.monotonic()
    lowered = _build_lowered(model_cfg, shape, mesh, quant=quant, qcfg=qcfg)
    t_lower = time.monotonic() - t0
    compiled = lowered.compile()
    t_compile = time.monotonic() - t0 - t_lower

    mem = compiled.memory_analysis()
    raw = _compiled_costs(compiled)
    if save_hlo:
        import gzip
        with gzip.open(save_hlo, "wt") as f:
            f.write(compiled.as_text())
    del compiled, lowered

    # --- depth-differential cost correction ---
    n_units = Model(model_cfg).n_units
    corrected = dict(raw)
    per_unit = None
    if depth_correct and n_units > 2:
        shallow = []
        for k in (1, 2):
            scfg = _shallow_cfg(model_cfg, k)
            low = _build_lowered(scfg, shape, mesh, quant=quant, qcfg=qcfg)
            shallow.append(_compiled_costs(low.compile()))
        per_unit = {f: shallow[1][f] - shallow[0][f]
                    for f in ("flops", "bytes_accessed", "collective_wire",
                              "collective_operand")}
        corrected = {
            f: shallow[0][f] + (n_units - 1) * per_unit[f]
            for f in per_unit
        }
        corrected["collectives"] = {
            k: shallow[0]["collectives"][k]
            + (n_units - 1) * (shallow[1]["collectives"][k]
                               - shallow[0]["collectives"][k])
            for k in RL.COLLECTIVES
        }

    chips = int(np.prod(list(mesh.shape.values())))
    flops = corrected["flops"]
    bytes_accessed = corrected["bytes_accessed"]
    terms = RL.roofline_terms(flops, bytes_accessed,
                              corrected["collective_wire"])

    tree = Model(cfg).build()
    n_params = pp.count_params(tree)
    n_active = _active_params(cfg, tree)
    tokens = shape.global_batch * (shape.seq_len
                                   if shape.kind in ("train", "prefill")
                                   else 1)
    mf_global = RL.model_flops(n_params, n_active, tokens,
                               "train" if shape.kind == "train" else "fwd")
    mf_per_chip = mf_global / chips

    record = {
        "arch": cfg.name,
        "shape": shape.name,
        "kind": shape.kind,
        "mesh": dict(mesh.shape),
        "chips": chips,
        "quant": quant,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
        },
        "cost_raw_scan": {k: raw[k] for k in
                          ("flops", "bytes_accessed", "collective_wire")},
        "cost": {"flops": flops, "bytes_accessed": bytes_accessed,
                 "collective_wire": corrected["collective_wire"],
                 "collective_operand": corrected["collective_operand"]},
        "cost_per_unit": per_unit,
        "n_units": n_units,
        "collectives": corrected.get("collectives", raw["collectives"]),
        "collective_counts": raw["collective_counts"],
        "roofline": terms,
        "model_flops_per_chip": mf_per_chip,
        "useful_flops_fraction": (mf_per_chip / flops) if flops else 0.0,
        "n_params": n_params,
        "n_active_params": n_active,
    }
    return record


def cell_name(arch: str, shape: str, mesh_kind: str, quant: str) -> str:
    return f"{arch}__{shape}__{mesh_kind}__{quant}"


def run_cells(cells, out_dir: str, quant: str = "qat", force: bool = False):
    os.makedirs(out_dir, exist_ok=True)
    meshes = {}
    results = []
    for arch_id, shape_name, mesh_kind in cells:
        name = cell_name(arch_id, shape_name, mesh_kind, quant)
        path = os.path.join(out_dir, name + ".json")
        if os.path.exists(path) and not force:
            with open(path) as f:
                results.append(json.load(f))
            print(f"[skip] {name}")
            continue
        cfg = C.get_config(arch_id)
        shape = SHAPES[shape_name]
        ok, why = shape_applicable(cfg, shape)
        if not ok:
            rec = {"arch": arch_id, "shape": shape_name, "mesh": mesh_kind,
                   "skipped": why}
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            print(f"[n/a ] {name}: {why}")
            continue
        if mesh_kind not in meshes:
            meshes[mesh_kind] = make_production_mesh(
                multi_pod=(mesh_kind == "multi"))
        print(f"[run ] {name} ...", flush=True)
        try:
            rec = lower_cell(cfg, shape, meshes[mesh_kind], quant=quant)
            rec["mesh_kind"] = mesh_kind
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            r = rec["roofline"]
            print(f"  ok lower={rec['lower_s']}s compile={rec['compile_s']}s "
                  f"compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s "
                  f"coll={r['collective_s']:.4f}s -> {r['bottleneck']}",
                  flush=True)
            results.append(rec)
        except Exception as e:
            print(f"  FAIL {type(e).__name__}: {e}")
            traceback.print_exc()
            with open(os.path.join(out_dir, name + ".err"), "w") as f:
                f.write(traceback.format_exc())
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--quant", default="qat", choices=["qat", "off"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    mesh_kinds = (["single", "multi"] if args.mesh == "both"
                  else [args.mesh])
    if args.all:
        archs = list(C.ARCH_IDS)
        shapes = list(SHAPES)
    else:
        archs = [args.arch] if args.arch else list(C.ARCH_IDS)
        shapes = [args.shape] if args.shape else list(SHAPES)
    cells = [(a, s, m) for a in archs for s in shapes for m in mesh_kinds]
    run_cells(cells, args.out, quant=args.quant, force=args.force)


if __name__ == "__main__":
    main()
