"""Aggregate dry-run JSON records into the EXPERIMENTS.md roofline tables."""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List


def load(out_dir: str) -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_bytes(b: float) -> str:
    return f"{b / 2**30:.2f}"


def roofline_table(recs: List[Dict], mesh_kind: str = "single") -> str:
    rows = []
    header = ("| arch | shape | bottleneck | compute s | memory s | coll s | "
              "roofline s | useful FLOP frac | HBM GiB/dev | coll GiB/dev |")
    sep = "|" + "---|" * 10
    rows.append(header)
    rows.append(sep)
    for r in recs:
        if r.get("skipped") or r.get("mesh_kind", "single") != mesh_kind:
            continue
        t = r["roofline"]
        mem = (r["memory"]["argument_bytes"] + r["memory"]["temp_bytes"])
        rows.append(
            f"| {r['arch']} | {r['shape']} | **{t['bottleneck']}** "
            f"| {t['compute_s']:.4f} | {t['memory_s']:.4f} "
            f"| {t['collective_s']:.4f} | {t['roofline_bound_s']:.4f} "
            f"| {r['useful_flops_fraction']:.3f} "
            f"| {fmt_bytes(mem)} "
            f"| {fmt_bytes(r['cost']['collective_wire'])} |")
    return "\n".join(rows)


def skipped_table(recs: List[Dict]) -> str:
    rows = ["| arch | shape | mesh | reason |", "|---|---|---|---|"]
    for r in recs:
        if r.get("skipped"):
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                        f"| {r['skipped']} |")
    return "\n".join(rows)


def summary(recs: List[Dict]) -> Dict:
    done = [r for r in recs if not r.get("skipped")]
    bottl = {}
    for r in done:
        b = r["roofline"]["bottleneck"]
        bottl[b] = bottl.get(b, 0) + 1
    return {"cells_compiled": len(done),
            "cells_skipped": len(recs) - len(done),
            "bottlenecks": bottl}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()
    recs = load(args.out)
    print(json.dumps(summary(recs), indent=1))
    print("\n## single-pod (16x16)\n")
    print(roofline_table(recs, "single"))
    print("\n## multi-pod (2x16x16)\n")
    print(roofline_table(recs, "multi"))
    print("\n## skipped\n")
    print(skipped_table(recs))


if __name__ == "__main__":
    main()
