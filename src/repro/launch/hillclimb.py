"""Perf-iteration tooling: compile a cell at shallow depth (exact costs,
fast turnaround) and report the dominant collective instructions + roofline
terms, so each hypothesis -> change -> measure cycle takes ~1 minute.

  PYTHONPATH=src python -m repro.launch.hillclimb --arch mistral-large-123b \
      --shape train_4k [--units 1] [--quant qat|off] [--full]
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
from collections import defaultdict


import repro.configs as C
from repro.configs.base import SHAPES
from repro.core.swis import QuantConfig
from repro.launch import roofline as RL
from repro.launch.dryrun import _build_lowered, _compiled_costs, _shallow_cfg
from repro.launch.mesh import make_production_mesh
from repro.configs.base import QuantPolicy


def top_collectives(hlo: str, k: int = 12):
    """Aggregate collective instructions by (kind, shape), largest first."""
    agg = defaultdict(lambda: [0, 0.0])
    for line in hlo.splitlines():
        m = RL._INSTR_RE.search(line)
        if not m or m.group("suffix") == "-done":
            continue
        kind = m.group("kind")
        shapes = RL._SHAPE_RE.findall(m.group("result"))
        size = sum(RL._shape_bytes(d, dims) for d, dims in shapes)
        g = RL._group_size(line)
        sig = f"{kind} g={g} {m.group('result')[:60]}"
        agg[sig][0] += 1
        agg[sig][1] += size
    rows = sorted(agg.items(), key=lambda kv: -kv[1][1])[:k]
    return [(sig, n, b) for sig, (n, b) in rows]


def measure(arch: str, shape_name: str, *, units: int = 1, quant: str = "qat",
            mesh_kind: str = "single", qcfg=None, show: int = 10,
            overrides=None):
    cfg = C.get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES[shape_name]
    qcfg = qcfg or QuantConfig(method="swis", n_shifts=4, group_size=4)
    model_cfg = cfg
    if shape.kind == "train":
        model_cfg = cfg.replace(quant=QuantPolicy(
            cfg=qcfg, mode="qat" if quant == "qat" else "off"))
    scfg = _shallow_cfg(model_cfg, units)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    lowered = _build_lowered(scfg, shape, mesh, quant=quant, qcfg=qcfg)
    compiled = lowered.compile()
    costs = _compiled_costs(compiled)
    hlo = compiled.as_text()
    terms = RL.roofline_terms(costs["flops"], costs["bytes_accessed"],
                              costs["collective_wire"])
    print(f"== {arch} x {shape_name} ({units} unit(s), quant={quant}) ==")
    print(f" flops/chip      {costs['flops']:.3e}")
    print(f" bytes/chip      {costs['bytes_accessed']:.3e}")
    print(f" coll wire/chip  {costs['collective_wire']:.3e}")
    print(f" terms: compute={terms['compute_s']:.4f}s "
          f"memory={terms['memory_s']:.4f}s coll={terms['collective_s']:.4f}s"
          f" -> {terms['bottleneck']}")
    print(" top collectives:")
    for sig, n, b in top_collectives(hlo, show):
        print(f"  {b/2**30:8.2f} GiB  x{n:<4d} {sig}")
    return costs, terms, hlo


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--units", type=int, default=1)
    ap.add_argument("--quant", default="qat")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--show", type=int, default=10)
    args = ap.parse_args()
    measure(args.arch, args.shape, units=args.units, quant=args.quant,
            mesh_kind=args.mesh, show=args.show)


if __name__ == "__main__":
    main()
