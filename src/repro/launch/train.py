"""Production training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --steps 200 --seq 512 --batch 32 --quant swis --n-shifts 3 \
      --workdir results/run1 [--smoke] [--mesh-data 2 --mesh-model 4]

On a real TPU fleet this runs one process per host (jax.distributed
initializes from the TPU environment); device meshes come from
``repro.launch.mesh.make_production_mesh``. On CPU it runs single-process
(optionally with a forced host-device mesh for integration testing).
"""
from __future__ import annotations

import argparse
import json

import jax

import repro.configs as C
from repro.configs.base import QuantPolicy
from repro.core.swis import QuantConfig
from repro.train.loop import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(C.ARCH_IDS))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--workdir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--quant", default="none",
                    choices=["none", "swis", "swis_c", "trunc"])
    ap.add_argument("--n-shifts", type=float, default=4)
    ap.add_argument("--group-size", type=int, default=4)
    ap.add_argument("--mesh-data", type=int, default=0)
    ap.add_argument("--mesh-model", type=int, default=0)
    args = ap.parse_args()

    cfg = C.get_smoke(args.arch) if args.smoke else C.get_config(args.arch)
    if args.quant != "none":
        cfg = cfg.replace(quant=QuantPolicy(
            cfg=QuantConfig(method=args.quant, n_shifts=args.n_shifts,
                            group_size=args.group_size),
            mode="qat"))
    mesh = None
    if args.mesh_data and args.mesh_model:
        mesh = jax.make_mesh((args.mesh_data, args.mesh_model),
                             ("data", "model"))

    tr = Trainer(cfg, seq_len=args.seq, global_batch=args.batch,
                 workdir=args.workdir, total_steps=args.steps,
                 ckpt_every=args.ckpt_every, warmup=args.warmup,
                 peak_lr=args.lr, mesh=mesh)
    out = tr.run(args.steps)
    print(json.dumps({"arch": cfg.name, "steps": args.steps,
                      "first_loss": out["first_loss"],
                      "last_loss": out["last_loss"],
                      "stragglers": out["straggler_events"]}, indent=1))


if __name__ == "__main__":
    main()
