"""Serving launcher: continuous-batching decode with optional SWIS-packed
weights (see docs/serving.md for the engine architecture).

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
      --requests 8 --prompt-len 8 --tokens 24 --packed --n-shifts 4

``--engine static`` runs the legacy lockstep DecodeEngine instead (equal
prompt lengths only) — useful for A/B-ing the two hot paths.

The continuous engine emits a periodic observability line every
``--metrics-every`` steps (queue depth, slot states, pool occupancy,
p50/p95 step latency — all from ``engine.metrics()``), a full phase +
cost report at the end, and with ``--trace-out PATH`` exports the trace:
a ``.json`` path gets Chrome trace-event JSON (open in Perfetto —
nested step/phase spans + per-request tracks), anything else the raw
request-lifecycle JSONL (see docs/serving.md "Observability").
"""
from __future__ import annotations

import argparse
import functools
import json
import sys
import time

import jax
import numpy as np

import repro.configs as C
from repro.core.swis import QuantConfig
from repro.models import params as pp
from repro.models.model import Model
from repro.serve import (ContinuousBatchingEngine, DecodeEngine,
                         EngineConfig, SamplingParams)
from repro.serve.metrics import format_report


def _metrics_line(step: int, m: dict) -> str:
    """One compact periodic report line from an ``engine.metrics()``
    snapshot."""
    sched = m["scheduler"]
    parts = [f"[step {step}]",
             f"queue={sched['queue_depth']}",
             f"active={sched['active_slots']}",
             f"prefilling={sched['prefilling_slots']}",
             f"finished={sched['finished']}"]
    if "block_pool" in m:
        parts.append(f"pool_occ={m['block_pool']['occupancy']:.2f}")
        parts.append(f"hit_rate={m['prefix_cache']['hit_rate']:.2f}")
    total = m["engine"]["phases"].get("step.total_s")
    if total and total["count"]:
        parts.append(f"p50_step={total['p50'] * 1e3:.2f}ms")
        parts.append(f"p95_step={total['p95'] * 1e3:.2f}ms")
    return " ".join(parts)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(C.ARCH_IDS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--engine", choices=("continuous", "static"),
                    default="continuous")
    ap.add_argument("--requests", type=int, default=8,
                    help="number of requests to serve")
    ap.add_argument("--n-slots", type=int, default=4,
                    help="concurrent decode slots (continuous engine)")
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=24)
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked prefill: at most this many prompt tokens "
                         "per engine step (continuous engine, block mode)")
    ap.add_argument("--fused", action="store_true",
                    help="fused mixed step: the per-step prefill chunk and "
                         "the decode batch share ONE dispatch (requires "
                         "--prefill-chunk)")
    ap.add_argument("--spec", action="store_true",
                    help="self-speculative decode: draft --spec-k tokens "
                         "with the model truncated to --draft-slices SWIS "
                         "bit-planes, verify in one full-precision launch "
                         "(continuous engine; token-exact vs plain decode)")
    ap.add_argument("--spec-k", type=int, default=3,
                    help="max draft tokens per speculative round")
    ap.add_argument("--draft-slices", type=int, default=None,
                    help="bit-slices kept for the draft pass (requires "
                         "--packed; default: full precision)")
    ap.add_argument("--packed", action="store_true")
    ap.add_argument("--n-shifts", type=int, default=4)
    ap.add_argument("--group-size", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--ckpt", default=None, help="checkpoint dir to serve")
    ap.add_argument("--metrics-every", type=int, default=25,
                    help="print a metrics line every N engine steps "
                         "(continuous engine; 0 disables)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="export the trace: *.json -> Chrome trace-event "
                         "JSON (load in Perfetto), else lifecycle JSONL")
    args = ap.parse_args()

    cfg = C.get_smoke(args.arch) if args.smoke else C.get_config(args.arch)
    cfg = cfg.replace(compute_dtype="float32")  # CPU demo
    model = Model(cfg)
    if args.ckpt:
        from repro.checkpoint import CheckpointManager
        from repro.train.steps import init_state

        template = init_state(pp.abstract_params(model.build()))
        state, _ = CheckpointManager(args.ckpt).restore(template)
        params = state.params
    else:
        params = pp.init_params(model.build(), jax.random.key(0))

    qcfg = QuantConfig(method="swis", n_shifts=args.n_shifts,
                       group_size=args.group_size)
    max_len = args.prompt_len + args.tokens + 1
    rng = np.random.default_rng(0)
    prompts = rng.integers(
        0, cfg.vocab, (args.requests, args.prompt_len)).astype(np.int32)

    if args.engine == "static":
        eng = DecodeEngine(cfg, params, max_len=max_len, batch=args.requests,
                           packed=args.packed, quant_cfg=qcfg)
        t0 = time.perf_counter()
        out = eng.generate(prompts, args.tokens,
                           temperature=args.temperature)
        dt = time.perf_counter() - t0
        sample = out[0]
    else:
        eng = ContinuousBatchingEngine(
            cfg, params, config=EngineConfig(
                max_len=max_len, n_slots=args.n_slots, packed=args.packed,
                quant_cfg=qcfg, prefill_chunk=args.prefill_chunk,
                fused_step=args.fused, spec_decode=args.spec,
                spec_k=args.spec_k, draft_slices=args.draft_slices))
        sp = functools.partial(SamplingParams, max_tokens=args.tokens,
                               temperature=args.temperature)
        rids = [eng.submit(p, sp(seed=i)) for i, p in enumerate(prompts)]
        t0 = time.perf_counter()
        results = {}
        step = 0
        while eng.scheduler.pending():
            for f in eng.step():
                results[f.rid] = np.concatenate([f.prompt, f.tokens])
            step += 1
            if args.metrics_every and step % args.metrics_every == 0:
                print(_metrics_line(step, eng.metrics()), file=sys.stderr)
        dt = time.perf_counter() - t0
        sample = results[rids[0]]
        print(format_report(eng.metrics_registry.snapshot(),
                            title="serve metrics"), file=sys.stderr)
        if args.trace_out:
            if args.trace_out.endswith(".json"):
                n = eng.tracer.export_chrome_trace(args.trace_out)
                print(f"trace: {n} Chrome trace events -> "
                      f"{args.trace_out} (open at https://ui.perfetto.dev)",
                      file=sys.stderr)
            else:
                n = eng.tracer.export_jsonl(args.trace_out)
                print(f"trace: {n} events -> {args.trace_out}",
                      file=sys.stderr)

    report = {"arch": cfg.name, "engine": args.engine,
              "requests": args.requests, "n_slots": args.n_slots,
              "tokens": args.tokens, "wall_s": round(dt, 2),
              "tok_per_s": round(args.requests * args.tokens / dt, 1)}
    if eng.pack_stats:
        report["packed_weights"] = eng.pack_stats["n_packed"]
        report["compression"] = round(eng.pack_stats["compression"], 2)
    if args.engine != "static":
        stats = eng.prefix_stats()
        if stats.get("enabled"):
            report["prefix_hit_rate"] = round(stats["hit_rate"], 3)
            report["prefill_tokens_saved"] = stats["saved_tokens"]
        snap = eng.metrics_registry.snapshot()
        if "cost.hbm_bytes" in snap["counters"]:
            # cost-model totals: predicted traffic of the issued
            # dispatches, and the model-implied bandwidth over the run
            report["cost_hbm_mib"] = round(
                snap["counters"]["cost.hbm_bytes"] / 2**20, 2)
            report["cost_gflops"] = round(
                snap["counters"]["cost.flops"] / 1e9, 3)
            report["cost_hbm_bytes_per_s"] = round(
                snap["gauges"].get("cost.hbm_bytes_per_s", 0.0), 1)
        tsum = eng.tracer.summary()
        if tsum["ttft_s"]:
            report["ttft_p50_s"] = round(tsum["ttft_s"]["p50"], 5)
            report["ttft_p95_s"] = round(tsum["ttft_s"]["p95"], 5)
        if tsum["tpot_s"]:
            report["tpot_p50_s"] = round(tsum["tpot_s"]["p50"], 6)
        if args.spec:
            c = eng.metrics_registry.snapshot()["counters"]
            report["spec_proposed"] = c.get("spec.proposed", 0)
            report["spec_accepted"] = c.get("spec.accepted", 0)
            report["spec_accept_rate"] = round(
                c.get("spec.accepted", 0)
                / max(c.get("spec.proposed", 0), 1), 3)
    print(json.dumps(report, indent=1))
    print("sample:", sample.tolist())


if __name__ == "__main__":
    main()
