"""Serving launcher: batched prefill+decode with optional SWIS-packed weights.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
      --batch 4 --prompt-len 8 --tokens 24 --packed --n-shifts 4
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

import repro.configs as C
from repro.core.swis import QuantConfig
from repro.models import params as pp
from repro.models.model import Model
from repro.serve import DecodeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(C.ARCH_IDS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=24)
    ap.add_argument("--packed", action="store_true")
    ap.add_argument("--n-shifts", type=int, default=4)
    ap.add_argument("--group-size", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--ckpt", default=None, help="checkpoint dir to serve")
    args = ap.parse_args()

    cfg = C.get_smoke(args.arch) if args.smoke else C.get_config(args.arch)
    cfg = cfg.replace(compute_dtype="float32")  # CPU demo
    model = Model(cfg)
    if args.ckpt:
        from repro.checkpoint import CheckpointManager
        from repro.train.steps import init_state

        template = init_state(pp.abstract_params(model.build()))
        state, _ = CheckpointManager(args.ckpt).restore(template)
        params = state.params
    else:
        params = pp.init_params(model.build(), jax.random.key(0))

    eng = DecodeEngine(
        cfg, params, max_len=args.prompt_len + args.tokens + 1,
        batch=args.batch, packed=args.packed,
        quant_cfg=QuantConfig(method="swis", n_shifts=args.n_shifts,
                              group_size=args.group_size))
    prompt = np.random.default_rng(0).integers(
        0, cfg.vocab, (args.batch, args.prompt_len)).astype(np.int32)
    t0 = time.perf_counter()
    out = eng.generate(prompt, args.tokens, temperature=args.temperature)
    dt = time.perf_counter() - t0
    report = {"arch": cfg.name, "batch": args.batch, "tokens": args.tokens,
              "wall_s": round(dt, 2),
              "tok_per_s": round(args.batch * args.tokens / dt, 1)}
    if eng.pack_stats:
        report["packed_weights"] = eng.pack_stats["n_packed"]
        report["compression"] = round(eng.pack_stats["compression"], 2)
    print(json.dumps(report, indent=1))
    print("sample:", out[0].tolist())


if __name__ == "__main__":
    main()
