"""Roofline-term derivation from compiled dry-run artifacts.

  compute term    = HLO_FLOPs / (chips * 197 TFLOP/s bf16)
  memory term     = HLO_bytes / (chips * 819 GB/s HBM)
  collective term = collective_bytes / (chips * 50 GB/s ICI)

``compiled.cost_analysis()`` reports the *per-device* SPMD program, so terms
are computed per chip directly (equivalent to the global/chips form).
collective_bytes is parsed from the HLO text: the summed operand sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instruction.
"""
from __future__ import annotations

import re
from typing import Any, Dict

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"\b(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"=\s*(?P<result>\([^=]*?\)|\S+)\s+"
    r"(?P<kind>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<suffix>-start|-done)?\(")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * b


def _group_size(line: str) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return m.group(1).count(",") + 1
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return 1


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Per-device collective traffic parsed from the compiled HLO.

    Post-optimization HLO prints operands without shapes, so sizes come from
    the *result* shape + the replica-group size g. Two accountings:

    * ``operand``: the literal summed operand sizes (all-gather operand =
      result/g, reduce-scatter operand = result*g, others = result).
    * ``wire``: per-device link bytes of bandwidth-optimal implementations
      (ring all-reduce 2P(g-1)/g, all-gather/all-to-all R(g-1)/g,
      reduce-scatter R(g-1), permute P) — the number the collective
      roofline term uses.
    """
    wire = {k: 0.0 for k in COLLECTIVES}
    operand = {k: 0.0 for k in COLLECTIVES}
    counts = {k: 0 for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if not m:
            continue
        if m.group("suffix") == "-done":
            continue  # counted at -start
        kind = m.group("kind")
        result = m.group("result")
        shapes = _SHAPE_RE.findall(result)
        if m.group("suffix") == "-start" and len(shapes) > 1:
            # async start returns (operand alias..., result); use the largest
            sizes = [_shape_bytes(d, dims) for d, dims in shapes]
            r = max(sizes)
        else:
            r = sum(_shape_bytes(d, dims) for d, dims in shapes)
        g = max(_group_size(line), 1)
        if g == 1 and kind != "collective-permute":
            # degenerate replica group: no traffic. (Permutes carry their
            # peers in source_target_pairs, not replica_groups — always
            # count their payload.)
            counts[kind] += 1
            continue
        if kind == "all-gather":
            wire[kind] += r * (g - 1) / g
            operand[kind] += r / g
        elif kind == "all-reduce":
            wire[kind] += 2.0 * r * (g - 1) / g
            operand[kind] += r
        elif kind == "reduce-scatter":
            wire[kind] += r * (g - 1)
            operand[kind] += r * g
        elif kind == "all-to-all":
            wire[kind] += r * (g - 1) / g
            operand[kind] += r
        else:  # collective-permute
            wire[kind] += r
            operand[kind] += r
        counts[kind] += 1
    out = {k: wire[k] for k in COLLECTIVES}
    out["total"] = sum(wire.values())
    out["operand_total"] = sum(operand.values())
    out["counts"] = counts
    return out


def roofline_terms(flops: float, bytes_accessed: float,
                   coll_bytes: float) -> Dict[str, float]:
    """All inputs are per-device. Returns seconds per step + bottleneck."""
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = bytes_accessed / HBM_BW
    t_coll = coll_bytes / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    terms["bottleneck"] = dom.replace("_s", "")
    total = max(t_compute, t_memory, t_coll)
    terms["roofline_bound_s"] = total
    terms["compute_fraction"] = t_compute / total if total else 0.0
    return terms


def model_flops(n_params: float, n_active_params: float, tokens: float,
                kind: str) -> float:
    """MODEL_FLOPS = 6*N*D (train) or 2*N*D (fwd-only), N = active params."""
    n = n_active_params or n_params
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * tokens
