"""Fault-tolerant training loop.

Responsibilities:
  * jit the train step with explicit state/batch shardings (when a mesh is
    given) and buffer donation,
  * checkpoint every ``ckpt_every`` steps (async), storing the data cursor +
    model RNG so a restart resumes bit-exactly,
  * restart semantics: ``Trainer(..., resume=True)`` picks up the newest
    checkpoint (elastic: the restore re-shards onto the current mesh),
  * failure injection (``fail_at_step``) used by the fault-tolerance tests,
  * straggler/preemption hook: a per-step deadline; overruns are logged and
    counted (on real fleets this triggers the supervisor's replace-node
    path; here it feeds the test that the loop survives slow steps).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs.base import ArchConfig
from repro.data import SyntheticPipeline
from repro.models import params as pp
from repro.models.model import Model
from repro.optim import AdamW, warmup_cosine
from repro.parallel import ctx as par_ctx
from repro.parallel.sharding import Rules
from repro.train.steps import TrainState, init_state, make_train_step


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class Trainer:
    cfg: ArchConfig
    seq_len: int = 128
    global_batch: int = 8
    workdir: Optional[str] = None
    peak_lr: float = 3e-3
    warmup: int = 20
    total_steps: int = 200
    ckpt_every: int = 0
    keep: int = 3
    seed: int = 0
    mesh: Any = None
    step_deadline_s: float = 0.0  # 0 => no deadline
    fail_at_step: int = -1  # inject a crash (tests)
    init_params: Any = None  # warm-start params (e.g. QAT retraining)

    def __post_init__(self):
        self.model = Model(self.cfg)
        self.pipeline = SyntheticPipeline(self.cfg, self.seq_len,
                                          self.global_batch, seed=self.seed)
        self.optimizer = AdamW()
        self.lr_fn = warmup_cosine(self.peak_lr, self.warmup, self.total_steps)
        self.ckpt = (CheckpointManager(self.workdir, keep=self.keep)
                     if self.workdir else None)
        self.rules = (Rules.for_arch(self.mesh, self.cfg)
                      if self.mesh is not None else None)
        self.straggler_events = 0

    # ------------------------------------------------------------------

    def state_shardings(self) -> TrainState:
        tree = self.model.build()
        pspec = self.rules.param_shardings(
            tree, fsdp=self.cfg.parallel.fsdp_params)
        fsdp_opt = self.cfg.parallel.fsdp_opt
        ospec = {"m": self.rules.param_shardings(tree, fsdp=fsdp_opt),
                 "v": self.rules.param_shardings(tree, fsdp=fsdp_opt)}
        return TrainState(step=self.rules.replicated(), params=pspec,
                          opt=ospec)

    def _jit_step(self):
        gather_sh = None
        if self.rules is not None and self.cfg.parallel.fsdp_params:
            gather_sh = self.rules.param_shardings(
                self.model.build(), fsdp=True)
        step = make_train_step(self.model, self.optimizer, self.lr_fn,
                               compute_shardings=gather_sh)
        if self.mesh is None:
            return jax.jit(step, donate_argnums=(0,))
        state_sh = self.state_shardings()
        batch_sh = self.rules.batch_specs(self.pipeline.batch_at(0))
        # out_shardings pinned to the input state sharding so donation works
        # step-over-step (XLA would otherwise pick its own output layout).
        return jax.jit(step, in_shardings=(state_sh, batch_sh),
                       out_shardings=(state_sh, None),
                       donate_argnums=(0,))

    def init_or_restore(self) -> tuple[TrainState, int]:
        tree = self.model.build()
        shardings = self.state_shardings() if self.rules is not None else None
        if self.ckpt is not None and self.ckpt.latest_step() is not None:
            template = init_state(pp.abstract_params(tree))
            state, meta = self.ckpt.restore(template, shardings=shardings)
            return state, int(meta["step"])
        params = (self.init_params if self.init_params is not None
                  else pp.init_params(tree, jax.random.key(self.seed)))
        state = init_state(params)
        if shardings is not None:
            state = jax.tree.map(jax.device_put, state, shardings)
        return state, 0

    # ------------------------------------------------------------------

    def run(self, n_steps: Optional[int] = None) -> Dict[str, float]:
        n_steps = n_steps or self.total_steps
        state, start = self.init_or_restore()
        step_fn = self._jit_step()
        history = []
        cm = par_ctx.use_rules(self.rules) if self.rules is not None else None
        if cm is not None:
            cm.__enter__()
        try:
            for step in range(start, n_steps):
                if step == self.fail_at_step:
                    raise SimulatedFailure(f"injected failure at step {step}")
                t0 = time.monotonic()
                batch = jax.tree.map(jnp.asarray,
                                     self.pipeline.batch_at(step))
                state, metrics = step_fn(state, batch)
                if self.step_deadline_s:
                    dt = time.monotonic() - t0
                    if dt > self.step_deadline_s:
                        self.straggler_events += 1
                history.append(float(metrics["loss"]))
                if (self.ckpt is not None and self.ckpt_every
                        and (step + 1) % self.ckpt_every == 0):
                    self.ckpt.save(step + 1, state,
                                   meta={"data": self.pipeline.state(step + 1),
                                         "loss": history[-1]},
                                   blocking=False)
        finally:
            if self.ckpt is not None:
                self.ckpt.wait()
            if cm is not None:
                cm.__exit__(None, None, None)
        return {"first_loss": history[0] if history else float("nan"),
                "last_loss": history[-1] if history else float("nan"),
                "losses": history, "state": state,
                "straggler_events": self.straggler_events}
