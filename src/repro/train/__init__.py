from repro.train.steps import TrainState, make_train_step, make_eval_step
from repro.train.loop import Trainer

__all__ = ["TrainState", "make_train_step", "make_eval_step", "Trainer"]
