"""Train / eval step factories (pjit-able, donation-friendly).

``make_train_step`` builds the full step: loss (+ SWIS QAT fake-quant in the
forward graph) -> grads (with optional gradient-accumulation scan over
microbatches) -> global-norm clip -> optional int8 gradient compression ->
AdamW update. All state transforms are pytree-generic so the same step works
for every architecture family.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.optim import AdamW, clip_by_global_norm
from repro.optim.compress import dequantize_grads, quantize_grads_int8


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    step: jnp.ndarray
    params: Any
    opt: Any


def init_state(params) -> TrainState:
    return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                      opt=AdamW().init(params))


def _split_micro(batch, n):
    def s(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape(n, b // n, *x.shape[1:])

    return jax.tree.map(s, batch)


def make_train_step(
    model: Model,
    optimizer: AdamW,
    lr_fn: Callable,
    *,
    max_grad_norm: float = 1.0,
    compute_shardings=None,
):
    """``compute_shardings``: optional TP-only (FSDP-free) sharding tree.
    When given, the compute-dtype copy of the params is constrained to it —
    this pins the ZeRO-3 data-axis all-gather AFTER the bf16 cast (and after
    QAT quantization), so the gather moves compute-dtype bytes, once per
    step, outside the rematted region."""
    cfg = model.cfg

    compute_dt = jnp.dtype(cfg.compute_dtype)

    def _cast_for_compute(params):
        # One bf16 cast per step: FSDP/TP weight all-gathers then move
        # compute-dtype bytes instead of fp32 (2x wire saving). Norm scales
        # and other 1-D leaves stay fp32 for stability; the fp32 masters
        # live in the optimizer state.
        out = jax.tree.map(
            lambda p: p.astype(compute_dt)
            if (hasattr(p, "ndim") and p.ndim >= 2
                and p.dtype == jnp.float32) else p,
            params)
        if compute_shardings is not None:
            out = jax.tree.map(jax.lax.with_sharding_constraint, out,
                               compute_shardings)
        # Pin the converts: without the barrier XLA sinks the fp32->bf16
        # cast into the layer scan and the ZeRO-3 gathers run on fp32.
        return jax.lax.optimization_barrier(out)

    if cfg.quant.mode == "qat":
        # Hoist SWIS quantization out of the rematted layer scan and the
        # microbatch loop: quantize every GEMM weight once per step (STE),
        # then run the model with per-layer quantization off. Shift
        # selection cost drops from (fwd + remat recompute) x n_micro to 1x.
        from repro.core.qat import quantize_tree

        inner = Model(cfg.replace(quant=dataclasses.replace(
            cfg.quant, mode="off")))

        def loss_fn(params, batch):
            return inner.loss(
                _cast_for_compute(quantize_tree(params, cfg.quant.cfg)),
                batch)
    else:
        def loss_fn(params, batch):
            return model.loss(_cast_for_compute(params), batch)

    def compute_grads(params, batch):
        n = cfg.parallel.grad_accum
        if n <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return grads, metrics
        micro = _split_micro(batch, n)

        def body(acc, mb):
            g_acc, m_acc = acc
            (loss, metrics), g = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                 g_acc, g)
            m_acc = jax.tree.map(lambda a, b: a + b, m_acc, metrics)
            return (g_acc, m_acc), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        m0 = {"loss": 0.0, "ce": 0.0, "aux": 0.0, "accuracy": 0.0}
        m0 = jax.tree.map(jnp.float32, m0)
        (grads, msum), _ = jax.lax.scan(body, (g0, m0), micro)
        grads = jax.tree.map(lambda g: g / n, grads)
        metrics = jax.tree.map(lambda m: m / n, msum)
        return grads, metrics

    def train_step(state: TrainState, batch):
        grads, metrics = compute_grads(state.params, batch)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        if cfg.parallel.grad_compress:
            # int8 compress/decompress in the update path; on multi-pod
            # deployments the cross-pod mean runs over the compressed
            # payload (see optim.compress.compressed_allreduce).
            q, s = quantize_grads_int8(grads)
            grads = dequantize_grads(q, s)
        lr = lr_fn(state.step)
        new_params, new_opt = optimizer.update(
            grads, state.opt, state.params, lr=lr, step=state.step)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["lr"] = lr
        return TrainState(step=state.step + 1, params=new_params,
                          opt=new_opt), metrics

    return train_step


def make_eval_step(model: Model):
    def eval_step(params, batch):
        _, metrics = model.loss(params, batch)
        return metrics

    return eval_step


def make_serve_steps(model: Model):
    """(prefill_fn, decode_fn) for the serving engine / dry-run."""

    def prefill(params, batch, cache):
        return model.prefill(params, batch, cache)

    def decode(params, tokens, cache, index):
        return model.decode_step(params, tokens, cache, index)

    return prefill, decode
