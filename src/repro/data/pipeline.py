"""Deterministic, shardable, checkpointable synthetic data pipeline.

``batch_at(step)`` is a pure function of (seed, step), which gives the three
properties large-scale training needs for free:

* restart determinism — resuming from a checkpoint replays the exact stream
  (the checkpoint stores only the step cursor),
* host sharding — each data-parallel host slices its rows of the global
  batch without coordination (``host_slice``),
* straggler-safe skipping — a skipped step is just a skipped integer.

The token stream is a per-sequence increment recurrence
``tok[t+1] = (tok[t] + a) mod vocab`` with a small per-sequence stride
``a`` — an induction-style structure a small LM masters quickly (infer the
stride from any adjacent pair), so quantization-induced accuracy loss is
well above the noise floor. Encoder batches embed the label stream through
a fixed random projection; VLM batches add deterministic patch embeddings.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from repro.configs.base import ArchConfig


@dataclasses.dataclass
class SyntheticPipeline:
    cfg: ArchConfig
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        b, s, v = self.global_batch, self.seq_len, cfg.vocab
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, step]))
        a = rng.integers(1, min(v, 9), (b, 1), dtype=np.int64)
        t0 = rng.integers(0, v, (b, 1), dtype=np.int64)
        toks = np.empty((b, s + 1), np.int64)
        toks[:, 0:1] = t0
        for t in range(s):
            toks[:, t + 1 : t + 2] = (toks[:, t : t + 1] + a) % v
        tokens = toks[:, :-1].astype(np.int32)
        labels = toks[:, 1:].astype(np.int32)

        if cfg.family == "encoder":
            # frames = fixed random projection of the label ids (learnable)
            proj_rng = np.random.default_rng(self.seed + 1)
            table = proj_rng.normal(0, 1, (v, cfg.d_model)).astype(np.float32)
            frames = table[labels % v]
            batch = {"frames": frames, "labels": labels}
        else:
            batch = {"tokens": tokens, "labels": labels}
        if cfg.family == "vlm":
            batch["patches"] = rng.normal(
                0, 1, (b, cfg.vlm.n_patches, cfg.vlm.vision_dim)
            ).astype(np.float32)
        return batch

    def host_slice(self, batch: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        if self.n_hosts == 1:
            return batch
        per = self.global_batch // self.n_hosts
        lo = self.host_id * per
        return {k: v[lo : lo + per] for k, v in batch.items()}

    # checkpointable cursor ------------------------------------------------
    def state(self, step: int) -> dict:
        return {"seed": self.seed, "step": int(step)}

    @staticmethod
    def resume_step(state: dict) -> int:
        return int(state["step"])
