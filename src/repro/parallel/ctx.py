"""Active-sharding context: model code annotates activations with *logical*
axes via :func:`constrain`; the launcher installs concrete rules (mesh +
logical->mesh mapping) around tracing. With no active rules (CPU unit tests)
constraints are no-ops, so model code never depends on a mesh.
"""
from __future__ import annotations

import contextlib

_ACTIVE: list = []


@contextlib.contextmanager
def use_rules(rules):
    _ACTIVE.append(rules)
    try:
        yield rules
    finally:
        _ACTIVE.pop()


def active_rules():
    return _ACTIVE[-1] if _ACTIVE else None


def constrain(x, logical_axes):
    rules = active_rules()
    if rules is None:
        return x
    return rules.constrain(x, logical_axes)
