"""Distribution: logical-axis sharding rules, remat, microbatching."""
