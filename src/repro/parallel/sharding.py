"""Logical-axis sharding rules (DP / FSDP / TP / SP / EP).

Model code tags parameters and activations with *logical* axis names; a
:class:`Rules` instance maps them to mesh axes with automatic divisibility
fallback (an axis that does not divide the dimension is dropped rather than
erroring — e.g. 8 KV heads on a 16-way model axis fall back to replication
and the KV cache picks up sequence sharding instead).

Default mapping (single-pod mesh ('data','model') / multi-pod
('pod','data','model')):

  batch            -> ('pod','data')   pure DP across pods
  vocab/heads/mlp/
  q_proj/kv_proj   -> 'model'          tensor parallelism
  expert           -> 'model'          expert parallelism (divisible MoE)
  seq              -> 'model'          sequence parallelism between blocks
  kv_seq           -> 'model'          decode KV-cache sharding
  embed/layers/...  -> replicated

FSDP: optimizer state (and optionally params) are additionally sharded over
'data' on the first still-unsharded divisible dimension (ZeRO-style).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.configs.base import ArchConfig
from repro.models.params import P, is_placeholder

DEFAULT_MAPPING = {
    "batch": ("pod", "data"),
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "q_proj": ("model",),
    "kv_proj": ("model",),
    "mlp": ("model",),
    "mlp2": None,
    "expert": ("model",),
    "seq": ("model",),
    "kv_seq": ("model",),
    "embed": None,
    "embed2": None,
    "head_dim": None,
    "layers": None,
}


@dataclasses.dataclass
class Rules:
    mesh: Mesh
    mapping: dict
    fsdp_axis: str = "data"

    @classmethod
    def for_arch(cls, mesh: Mesh, cfg: Optional[ArchConfig] = None,
                 overrides: Optional[dict] = None) -> "Rules":
        mapping = dict(DEFAULT_MAPPING)
        if cfg is not None and not cfg.parallel.sp:
            mapping["seq"] = None
        if overrides:
            mapping.update(overrides)
        return cls(mesh=mesh, mapping=mapping)

    # ------------------------------------------------------------------

    def _axis_size(self, name: str) -> int:
        return int(self.mesh.shape[name]) if name in self.mesh.shape else 0

    def spec_for(self, axes, shape) -> PartitionSpec:
        """Logical axes -> PartitionSpec with divisibility fallback."""
        used = set()
        out = []
        for dim, ax in zip(shape, axes):
            entry = self.mapping.get(ax) if ax is not None else None
            if entry is None:
                out.append(None)
                continue
            names = (entry,) if isinstance(entry, str) else tuple(entry)
            names = [n for n in names if self._axis_size(n) and n not in used]
            total = int(np.prod([self._axis_size(n) for n in names])) if names else 0
            if not names or dim % max(total, 1):
                # try progressively smaller prefixes (e.g. drop 'pod')
                while names and dim % int(np.prod([self._axis_size(n) for n in names])):
                    names = names[:-1]
            if not names:
                out.append(None)
                continue
            used.update(names)
            out.append(tuple(names) if len(names) > 1 else names[0])
        return PartitionSpec(*out)

    def sharding_for(self, axes, shape) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(axes, shape))

    def constrain(self, x, logical_axes):
        if len(logical_axes) != x.ndim:
            raise ValueError(f"axes {logical_axes} vs shape {x.shape}")
        return jax.lax.with_sharding_constraint(
            x, self.sharding_for(logical_axes, x.shape))

    # ------------------------------------------------------------------

    def param_specs(self, tree, fsdp: bool = False):
        """PartitionSpec tree for a placeholder tree."""

        def one(p: P):
            spec = self.spec_for(p.axes, p.shape)
            if fsdp:
                spec = self._fsdp_spec(spec, p.shape)
            return spec

        return jax.tree.map(one, tree, is_leaf=is_placeholder)

    def param_shardings(self, tree, fsdp: bool = False):
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            self.param_specs(tree, fsdp=fsdp))

    def _fsdp_spec(self, spec: PartitionSpec, shape) -> PartitionSpec:
        """Shard the first unsharded divisible dim over the data axis."""
        n = self._axis_size(self.fsdp_axis)
        if not n:
            return spec
        used = set()
        for e in spec:
            if e is None:
                continue
            used.update((e,) if isinstance(e, str) else e)
        if self.fsdp_axis in used:
            return spec
        entries = list(spec)
        best = -1
        for i, (dim, e) in enumerate(zip(shape, entries)):
            if e is None and dim % n == 0 and dim >= n:
                if best < 0 or shape[i] > shape[best]:
                    best = i
        if best < 0:
            return spec
        entries[best] = self.fsdp_axis
        return PartitionSpec(*entries)

    # ------------------------------------------------------------------

    def batch_specs(self, batch_tree):
        """Input-batch shardings: leading dim is the (global) batch."""

        def one(x):
            shape = x.shape
            axes = ("batch",) + (None,) * (len(shape) - 1)
            return NamedSharding(self.mesh, self.spec_for(axes, shape))

        return jax.tree.map(one, batch_tree)

    def replicated(self):
        return NamedSharding(self.mesh, PartitionSpec())
