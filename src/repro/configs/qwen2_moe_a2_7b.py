"""qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
24L d_model=2048 16H (MHA kv=16) vocab=151936; MoE: 60 routed experts top-4
(expert d_ff=1408, fine-grained) + 4 shared experts (4*1408=5632 hidden)."""
from repro.configs.base import ArchConfig, MoEConfig, ParallelConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=151936,
    moe=MoEConfig(n_experts=60, top_k=4, n_shared=4, d_ff_expert=1408,
                  shard="auto", n_experts_padded=64),
    parallel=ParallelConfig(remat="full", grad_accum=1),
)

SMOKE = ArchConfig(
    name="qwen2-moe-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=48,
    vocab=512,
    vocab_pad_multiple=16,
    moe=MoEConfig(n_experts=8, top_k=4, n_shared=2, d_ff_expert=48,
                  group_tokens=64),
)
