"""mistral-large-123b [hf:mistralai/Mistral-Large-Instruct-2407; unverified]
88L d_model=12288 96H (GQA kv=8, d_head=128) d_ff=28672 vocab=32768."""
from repro.configs.base import ArchConfig, ParallelConfig

CONFIG = ArchConfig(
    name="mistral-large-123b",
    family="dense",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_head=128,
    d_ff=28672,
    vocab=32768,
    parallel=ParallelConfig(remat="full", grad_accum=16, fsdp_params=True),
)

SMOKE = ArchConfig(
    name="mistral-large-smoke",
    family="dense",
    n_layers=3,
    d_model=96,
    n_heads=6,
    n_kv_heads=2,
    d_head=16,
    d_ff=192,
    vocab=512,
    vocab_pad_multiple=16,
)
