"""Assigned-architecture registry: ``get_config('<arch-id>')`` returns the
exact published config; ``get_smoke('<arch-id>')`` the reduced same-family
smoke config. Arch ids use dashes (CLI form): e.g. ``--arch qwen2-moe-a2.7b``.
"""
from __future__ import annotations

import importlib

from repro.configs.base import (ArchConfig, GriffinConfig, Mamba2Config,
                                MoEConfig, ParallelConfig, QuantPolicy,
                                ShapeConfig, SHAPES, VLMConfig,
                                shape_applicable)

_MODULES = {
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "dbrx-132b": "dbrx_132b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "mistral-large-123b": "mistral_large_123b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "smollm-135m": "smollm_135m",
    "deepseek-7b": "deepseek_7b",
    "mamba2-2.7b": "mamba2_2_7b",
    "hubert-xlarge": "hubert_xlarge",
}

ARCH_IDS = tuple(_MODULES)


def _module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get_config(arch_id: str) -> ArchConfig:
    return _module(arch_id).CONFIG


def get_smoke(arch_id: str) -> ArchConfig:
    return _module(arch_id).SMOKE
