"""dbrx-132b [hf:databricks/dbrx-base; unverified]
40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352; MoE 16 experts top-4."""
from repro.configs.base import ArchConfig, MoEConfig, ParallelConfig

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab=100352,
    moe=MoEConfig(n_experts=16, top_k=4, n_shared=0, d_ff_expert=10752,
                  shard="auto"),
    parallel=ParallelConfig(remat="full", grad_accum=4, fsdp_params=True),
)

SMOKE = ArchConfig(
    name="dbrx-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_ff=96,
    vocab=512,
    vocab_pad_multiple=16,
    moe=MoEConfig(n_experts=4, top_k=2, n_shared=0, d_ff_expert=96,
                  group_tokens=64),
)
