"""Architecture + run configuration.

One :class:`ArchConfig` instance fully describes a model (family, dims,
per-family extras), its quantization policy (the paper's technique as a
first-class feature), and its parallelism knobs. Every assigned architecture
provides a module in this package exposing ``CONFIG`` (exact published dims)
and ``SMOKE`` (reduced same-family config for CPU tests).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.swis import QuantConfig

FAMILIES = ("dense", "moe", "griffin", "mamba2", "encoder", "vlm")


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 2
    n_shared: int = 0  # always-on shared experts (DeepSeek/Qwen style)
    d_ff_expert: int = 0  # per-expert hidden (0 => use arch d_ff)
    capacity_factor: float = 1.25
    group_tokens: int = 512  # GShard-style dispatch group size
    router_aux_weight: float = 0.01
    # 'ep'   : experts sharded over the model axis (needs E % model == 0)
    # 'tp'   : expert d_ff sharded over the model axis
    # 'auto' : ep when divisible else tp
    shard: str = "auto"
    # Pad the expert count to this value (0 = off) so EP divides the mesh
    # model axis; padded experts get -inf router logits and are never
    # routed. Beyond-paper optimization (see EXPERIMENTS.md §Perf): avoids
    # the TP fallback's full-dispatch-tensor all-reduces.
    n_experts_padded: int = 0

    @property
    def e_total(self) -> int:
        return max(self.n_experts_padded, self.n_experts)


@dataclasses.dataclass(frozen=True)
class GriffinConfig:
    lru_width: int = 2560
    conv_width: int = 4
    window: int = 2048  # local attention window
    pattern: Tuple[str, ...] = ("rec", "rec", "attn")
    lru_c: float = 8.0


@dataclasses.dataclass(frozen=True)
class Mamba2Config:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    chunk: int = 256
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class VLMConfig:
    cross_every: int = 5  # a cross-attn block after every N-th self block
    n_patches: int = 1024  # stub frontend: precomputed patch embeddings
    vision_dim: int = 4096  # dim of the (projected) patch embeddings


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    remat: str = "full"  # 'none' | 'full' | 'dots'
    scan_layers: bool = True
    grad_accum: int = 1
    sp: bool = True  # sequence-shard residuals over the model axis
    fsdp_params: bool = False  # additionally shard params over data axis
    fsdp_opt: bool = True  # shard optimizer state over data axis
    grad_compress: bool = False  # int8-compressed gradient all-reduce


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    cfg: QuantConfig = dataclasses.field(default_factory=QuantConfig)
    mode: str = "off"  # 'off' | 'qat' | 'ptq'
    quantize_embeddings: bool = False
    # Stripes-like baseline: per-layer LSB truncation of 8-bit activations
    # before every GEMM (paper §5 'Act. Trunc.'). 0 = off.
    act_shifts: int = 0
    # Truncated-precision execution over SWIS-packed weights: evaluate
    # only the k most significant bit-slices of every packed GEMM (the
    # bit-serial PE ends its shift-accumulate loop k slices in). None =
    # full precision. The serve engine's self-speculative draft model is
    # the same packed params under a policy with keep_slices set.
    keep_slices: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str = "model"
    family: str = "dense"
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_head: int = 0  # 0 => d_model // n_heads
    d_ff: int = 1024
    vocab: int = 1024
    act: str = "silu"  # 'silu' (SwiGLU) | 'gelu' (GeGLU or plain)
    glu: bool = True
    norm: str = "rms"  # 'rms' | 'ln'
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    vocab_pad_multiple: int = 128
    causal: bool = True
    moe: Optional[MoEConfig] = None
    griffin: Optional[GriffinConfig] = None
    mamba2: Optional[Mamba2Config] = None
    vlm: Optional[VLMConfig] = None
    quant: QuantPolicy = dataclasses.field(default_factory=QuantPolicy)
    parallel: ParallelConfig = dataclasses.field(default_factory=ParallelConfig)
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # attention memory: KV-chunked online-softmax block size
    attn_chunk: int = 1024
    # which shapes are valid for this arch ('train', 'prefill', 'decode', 'long')
    sub_quadratic: bool = False  # True => long_500k is runnable
    has_decoder: bool = True  # False for encoder-only (no decode shapes)

    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(f"unknown family {self.family}")

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return (self.vocab + m - 1) // m * m

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned (arch x shape) cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether a shape cell applies to this arch (per-assignment skips)."""
    if shape.kind == "decode" and not arch.has_decoder:
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, "long_500k requires sub-quadratic attention (see DESIGN.md)"
    return True, ""
