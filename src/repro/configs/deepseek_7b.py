"""deepseek-7b [arXiv:2401.02954; hf]
30L d_model=4096 32H (MHA kv=32) d_ff=11008 vocab=102400, llama arch."""
from repro.configs.base import ArchConfig, ParallelConfig

CONFIG = ArchConfig(
    name="deepseek-7b",
    family="dense",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab=102400,
    parallel=ParallelConfig(remat="full"),
)

SMOKE = ArchConfig(
    name="deepseek-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=176,
    vocab=512,
    vocab_pad_multiple=16,
)
