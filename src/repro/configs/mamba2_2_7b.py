"""mamba2-2.7b [arXiv:2405.21060; unverified]
64L d_model=2560 attn-free (SSD, state=128, head_dim=64, expand=2)
vocab=50280 (padded to 50432 for sharding divisibility). Sub-quadratic:
O(1) recurrent state carries the long_500k decode shape."""
from repro.configs.base import ArchConfig, Mamba2Config, ParallelConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    family="mamba2",
    n_layers=64,
    d_model=2560,
    n_heads=1,  # attention-free; kept for config uniformity
    n_kv_heads=1,
    d_ff=0,
    vocab=50280,
    vocab_pad_multiple=128,  # 50280 -> 50432 (divisible by 16 TP shards)
    glu=False,
    mamba2=Mamba2Config(d_state=128, head_dim=64, expand=2, chunk=256),
    sub_quadratic=True,
    parallel=ParallelConfig(remat="full"),
)

SMOKE = ArchConfig(
    name="mamba2-smoke",
    family="mamba2",
    n_layers=3,
    d_model=64,
    n_heads=1,
    n_kv_heads=1,
    d_ff=0,
    vocab=512,
    vocab_pad_multiple=16,
    glu=False,
    mamba2=Mamba2Config(d_state=16, head_dim=16, expand=2, chunk=16),
    sub_quadratic=True,
)
