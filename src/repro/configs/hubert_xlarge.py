"""hubert-xlarge [arXiv:2106.07447; unverified]
48L d_model=1280 16H (MHA kv=16) d_ff=5120 vocab=504 (padded 512).
Encoder-only (bidirectional, LayerNorm, GeLU MLP, no GLU): no decode
shapes. The conv feature-extractor frontend is a STUB — input_specs()
provides precomputed frame embeddings (B, S, 1280)."""
from repro.configs.base import ArchConfig, ParallelConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="encoder",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    vocab_pad_multiple=8,  # 504 -> 504 (already /8); head divisibility n/a
    act="gelu",
    glu=False,
    norm="ln",
    causal=False,
    has_decoder=False,
    parallel=ParallelConfig(remat="full"),
)

SMOKE = ArchConfig(
    name="hubert-smoke",
    family="encoder",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=56,
    vocab_pad_multiple=8,
    act="gelu",
    glu=False,
    norm="ln",
    causal=False,
    has_decoder=False,
)
