"""recurrentgemma-2b [arXiv:2402.19427; hf]
26L d_model=2560 10H (MQA kv=1, d_head=256) d_ff=7680 vocab=256000.
RG-LRU + local attention, pattern (rec, rec, attn) — 8 scanned units + 2
tail rec layers. Sub-quadratic (bounded window + O(1) recurrent state)."""
from repro.configs.base import ArchConfig, GriffinConfig, ParallelConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="griffin",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_head=256,
    d_ff=7680,
    vocab=256000,
    act="gelu",
    tie_embeddings=True,  # Gemma family ties input/output embeddings
    griffin=GriffinConfig(lru_width=2560, conv_width=4, window=2048,
                          pattern=("rec", "rec", "attn_local")),
    sub_quadratic=True,
    parallel=ParallelConfig(remat="full"),
)

SMOKE = ArchConfig(
    name="recurrentgemma-smoke",
    family="griffin",
    n_layers=4,  # one scanned (rec, rec, attn_local) unit + one tail rec
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    d_head=16,
    d_ff=128,
    vocab=512,
    vocab_pad_multiple=16,
    act="gelu",
    griffin=GriffinConfig(lru_width=64, conv_width=4, window=8,
                          pattern=("rec", "rec", "attn_local")),
    sub_quadratic=True,
)
