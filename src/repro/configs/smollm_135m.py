"""smollm-135m [hf:HuggingFaceTB/SmolLM-135M; hf]
30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152, tied embeddings.
This is the ~100M-class arch used by the end-to-end QAT training example."""
from repro.configs.base import ArchConfig, ParallelConfig

CONFIG = ArchConfig(
    name="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    d_ff=1536,
    vocab=49152,
    tie_embeddings=True,
    parallel=ParallelConfig(remat="full"),
)

SMOKE = ArchConfig(
    name="smollm-smoke",
    family="dense",
    n_layers=3,
    d_model=60,
    n_heads=3,
    n_kv_heads=3,
    d_ff=160,
    vocab=512,
    vocab_pad_multiple=16,
    tie_embeddings=True,
)
