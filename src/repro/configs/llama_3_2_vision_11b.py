"""llama-3.2-vision-11b [hf:meta-llama/Llama-3.2-11B-Vision; unverified]
40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256; gated cross-attn
image layers every 5th block. Vision frontend is a STUB: input_specs()
provides precomputed, projected patch embeddings (B, 1024, 4096)."""
from repro.configs.base import ArchConfig, ParallelConfig, VLMConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=128256,
    vlm=VLMConfig(cross_every=5, n_patches=1024, vision_dim=4096),
    parallel=ParallelConfig(remat="full", grad_accum=2),
)

SMOKE = ArchConfig(
    name="llama-vision-smoke",
    family="vlm",
    n_layers=4,  # two (attn, self_cross) units
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=512,
    vocab_pad_multiple=16,
    vlm=VLMConfig(cross_every=2, n_patches=16, vision_dim=64),
)
