"""Learning-rate schedules (callables of the step scalar)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(peak_lr: float, warmup: int, total: int, floor: float = 0.0):
    def lr(step):
        s = jnp.asarray(step, jnp.float32)
        warm = peak_lr * (s + 1.0) / max(warmup, 1)
        frac = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (peak_lr - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(s < warmup, warm, cos)

    return lr


def warmup_linear(peak_lr: float, warmup: int, total: int, floor: float = 0.0):
    def lr(step):
        s = jnp.asarray(step, jnp.float32)
        warm = peak_lr * (s + 1.0) / max(warmup, 1)
        frac = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        lin = peak_lr + (floor - peak_lr) * frac
        return jnp.where(s < warmup, warm, lin)

    return lr
