"""Pure-JAX optimizer substrate (no optax dependency)."""
from repro.optim.adamw import AdamW
from repro.optim.schedule import warmup_cosine, warmup_linear
from repro.optim.clip import clip_by_global_norm, global_norm
from repro.optim.compress import quantize_grads_int8, dequantize_grads, compressed_allreduce

__all__ = ["AdamW", "warmup_cosine", "warmup_linear", "clip_by_global_norm",
           "global_norm", "quantize_grads_int8", "dequantize_grads",
           "compressed_allreduce"]
