"""Global-norm gradient clipping."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(leaf.astype(jnp.float32)))
                        for leaf in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(
        lambda a: (a.astype(jnp.float32) * scale).astype(a.dtype),
        tree), norm
