"""Gradient compression for cross-pod reduction.

``quantize_grads_int8`` / ``dequantize_grads`` implement per-leaf absmax int8
quantization. ``compressed_allreduce`` is the shard_map building block for a
bandwidth-compressed cross-pod all-reduce: each pod all-gathers the int8
payload (1 byte/element instead of 4) and sums locally in fp32. At 2 pods
this is ~2x the bytes of a perfect ring all-reduce segment but 4x smaller
elements => ~2x net wire saving; at P pods the saving is 4/P per hop against
ring all-reduce, so it is enabled (cfg.parallel.grad_compress) for the
pod axis only, where links are the scarce resource.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp


def quantize_grads_int8(tree):
    """Per-leaf symmetric int8 quantization. Returns (q_tree, scale_tree)."""

    def q(g):
        g32 = g.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
        qv = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        return qv, scale

    flat = jax.tree.map(q, tree)
    qt = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    st = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    return qt, st


def dequantize_grads(q_tree, scale_tree):
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s, q_tree,
                        scale_tree)


def compressed_allreduce(x: jnp.ndarray, axis_name: str):
    """Mean over ``axis_name`` with int8-compressed payload.

    Call inside shard_map. Each participant quantizes its shard, all-gathers
    the int8 payload + fp32 scale, and averages locally in fp32.
    """
    scale = jnp.maximum(jnp.max(jnp.abs(x.astype(jnp.float32))), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    qs = jax.lax.all_gather(q, axis_name)  # (P, ...) int8  — compressed wire
    ss = jax.lax.all_gather(scale, axis_name)  # (P,) fp32
    deq = qs.astype(jnp.float32) * ss.reshape((-1,) + (1,) * x.ndim)
    return deq.mean(axis=0).astype(x.dtype)
