"""AdamW with decoupled weight decay (pure JAX, pytree-generic).

Optimizer state is a pytree congruent with params (fp32 moments), so the
sharding rules can FSDP-shard it leaf-by-leaf.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamW:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    # weight decay is skipped for 1-D leaves (norm scales, biases)
    decay_min_ndim: int = 2

    def init(self, params):
        def zeros(p):
            return jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(self, grads, opt_state, params, *, lr, step):
        b1, b2, eps = self.b1, self.b2, self.eps
        count = step + 1
        c1 = 1.0 - b1 ** count.astype(jnp.float32)
        c2 = 1.0 - b2 ** count.astype(jnp.float32)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m_new = b1 * m + (1 - b1) * g32
            v_new = b2 * v + (1 - b2) * g32 * g32
            mhat = m_new / c1
            vhat = v_new / c2
            delta = mhat / (jnp.sqrt(vhat) + eps)
            if self.weight_decay and p.ndim >= self.decay_min_ndim:
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            p_new = p.astype(jnp.float32) - lr * delta
            return p_new.astype(p.dtype), m_new, v_new

        flat = jax.tree.map(upd, grads, opt_state["m"], opt_state["v"], params)
        new_params = jax.tree.map(lambda t: t[0], flat,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], flat,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda t: t[2], flat,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m, "v": new_v}
