"""Serve engines over SWIS-packed weights.

Two engines share the same model, packing path, and seeded sampler:

* :class:`ContinuousBatchingEngine` — the serving hot path. A
  :class:`~repro.serve.scheduler.RequestScheduler` admits requests from a
  queue into free slots of a :class:`~repro.serve.kv_cache.SlotKVCache`;
  admitted requests prefill into their slot (grouped by prompt length)
  while the other slots keep decoding, one batched per-slot decode step at
  a time (``submit`` / ``step`` / ``drain``). With ``packed=True`` the
  whole hot path runs on SWIS bit-plane weights (``pack_tree``) — HBM
  weight traffic per decode step is the compressed bytes, the paper's
  serving-side win.

* :class:`DecodeEngine` — the legacy static-batch engine (one lockstep
  batch, fresh cache per call). Kept as the parity oracle:
  ``ContinuousBatchingEngine.generate`` reproduces its greedy tokens
  exactly, and its seeded-temperature tokens exactly too because both
  engines sample through :func:`sample_step` with identical per-row keys.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import warnings
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.swis import QuantConfig
from repro.models import params as pp
from repro.models.model import Model
from repro.serve import trace as tr
from repro.serve.config import EngineConfig, SamplingParams
from repro.serve.costmodel import CostModel
from repro.serve.kv_cache import SlotKVCache
from repro.serve.metrics import MetricsRegistry, cost_buckets
from repro.serve.prefix_cache import BlockPool, RadixPrefixCache
from repro.serve.quantized import pack_tree, total_slices
from repro.serve.scheduler import Finished, RequestScheduler
from repro.serve.trace import RequestTracer

# shared bucket edges for per-dispatch cost histograms (the registry
# only consults edges when a histogram is first created)
_COST_EDGES = cost_buckets()
_COST_FIELDS = ("flops", "hbm_bytes", "swis_cycles")


@jax.jit
def sample_step(logits, keys, steps, temps):
    """Seeded per-row sampling shared by both engines.

    Row r draws from ``categorical(fold_in(keys[r], steps[r]),
    logits[r] / temps[r])`` (argmax when temps[r] <= 0). Because the key is
    per-row, a request's tokens depend only on its own (key, step, logits)
    — not on batch size, slot position, or who else is in flight.
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def one(key, step, row, t):
        k = jax.random.fold_in(key, step)
        return jax.random.categorical(
            k, row / jnp.maximum(t, 1e-6)).astype(jnp.int32)

    sampled = jax.vmap(one)(keys, steps, logits, temps)
    return jnp.where(temps <= 0.0, greedy, sampled)


def _row_keys(rng, b: int):
    """Per-row sampling keys for a lockstep batch: row r gets
    fold_in(rng, r) — the same derivation ``generate()`` compat uses."""
    return jax.vmap(lambda r: jax.random.fold_in(rng, r))(
        jnp.arange(b, dtype=jnp.uint32))


def _maybe_pack(cfg: ArchConfig, params, packed: bool,
                quant_cfg: Optional[QuantConfig]):
    """Common packing path: returns (cfg, params, pack_stats)."""
    if not packed:
        return cfg, params, None
    qcfg = quant_cfg or cfg.quant.cfg
    params, stats = pack_tree(params, qcfg)
    # record the pack method so dense()/moe dispatch the right
    # (consecutive vs sparse) unpack semantics
    from repro.configs.base import QuantPolicy

    return cfg.replace(quant=QuantPolicy(cfg=qcfg, mode="off")), params, stats


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------


class ContinuousBatchingEngine:
    """Step-driven serve engine: requests join mid-flight.

    API: construct with ``ContinuousBatchingEngine(cfg, params,
    config=EngineConfig(...))``; ``submit(prompt_1d,
    SamplingParams(max_tokens, ...)) -> rid``; ``step()`` runs one
    scheduler round (admit + prefill new slots, one batched decode step)
    and returns the requests that finished; ``drain()`` steps until idle.
    ``generate`` is the drop-in static-batch compatibility wrapper. The
    pre-:class:`EngineConfig` loose-kwarg construction and the positional
    ``submit(prompt, n_tokens, temperature=..., seed=...)`` signature
    still work for one release behind ``DeprecationWarning`` shims
    (docs/serving.md has the migration table).

    With ``prefix_cache=True`` (default, for families whose caches are
    uniform attention ring buffers) the KV cache is a physical-block arena
    behind per-slot block tables, and a :class:`RadixPrefixCache` maps
    committed prompt prefixes to block chains: an admitted request
    references the longest cached block-aligned prefix of its prompt
    (refcount++, zero recompute) and prefills only the uncached suffix;
    on completion its full blocks are committed back into the trie.
    ``prefix_stats()`` reports hit rate and prefill tokens saved.

    With ``prefill_chunk=N`` (block mode only) admitted prompts prefill at
    most ``N`` tokens per ``step()``: the uncached part of each prompt is
    split into fixed chunks, the slot sits in the scheduler's PREFILLING
    phase while its chunks land, and every other slot keeps decoding each
    step — per-step latency is bounded by one chunk of prefill plus one
    batched decode regardless of prompt length, killing the head-of-line
    blocking a monolithic prefill causes. Output is token-exact vs
    unchunked prefill. ``prefill_backlog`` caps how many chunk-prefill
    groups may be in flight before admission pauses (in-flight chunk work
    the admission gate accounts for).

    With ``use_paged_kernel=True`` (block mode only) the decode step runs
    the fused paged-attention kernel: attention reads each slot's K/V
    through its block table *in place* instead of materializing the
    gathered arena view every step (``paged_impl`` overrides the backend
    auto-pick — ``"pallas"`` on TPU, ``"xla"`` scan fallback elsewhere).
    Token-exact vs the gather path; see ``docs/serving.md``.

    With ``fused_step=True`` (requires ``prefill_chunk``) a step that
    services a chunk-prefill group issues ONE ``mixed_step`` dispatch
    covering the whole decode batch *and* the chunk: the chunk's rows are
    concatenated after the per-slot decode rows, every row routes through
    its own block table with a per-row valid-token count, and the chunk's
    K/V commits into the arena inside the same launch — the separate
    chunk-then-decode sequencing (two dispatches plus a host-side block
    commit) remains the token-exact parity reference when off.

    With ``spec_decode=True`` (block mode only) pure-decode steps run
    self-speculatively: ``spec_k`` draft tokens are proposed by the model
    itself with SWIS weights truncated to ``draft_slices`` bit-planes
    (``None``: full precision), one full-precision verify launch scores
    every proposal, and the longest matching prefix plus the verify's
    bonus token is accepted — several tokens per step when drafts agree,
    never fewer than one, and token-exact vs. plain decode for every
    accept pattern (see docs/serving.md "Speculative decode"). Steps that
    service a fused chunk group still run the plain ``_mixed_once`` path.
    """

    def __init__(self, cfg: ArchConfig, params: Any,
                 config: Optional[EngineConfig] = None, **legacy):
        if legacy:
            if config is not None:
                raise TypeError(
                    "pass either config=EngineConfig(...) or the legacy "
                    "loose kwargs, not both")
            known = {f.name for f in dataclasses.fields(EngineConfig)}
            unknown = set(legacy) - known
            if unknown:
                raise TypeError(
                    f"unknown engine kwargs {sorted(unknown)}; valid "
                    f"EngineConfig fields: {sorted(known)}")
            warnings.warn(
                "ContinuousBatchingEngine(cfg, params, max_len=..., ...) "
                "loose kwargs are deprecated; pass "
                "config=EngineConfig(...) instead", DeprecationWarning,
                stacklevel=2)
            config = EngineConfig(**legacy)
        elif config is None:
            config = EngineConfig()
        elif not isinstance(config, EngineConfig):
            raise TypeError(
                f"config must be an EngineConfig, got "
                f"{type(config).__name__} (legacy positional max_len is "
                f"not supported here — pass EngineConfig(max_len=...))")
        self.config = config
        self.cfg, self.params, self.pack_stats = _maybe_pack(
            cfg, params, config.packed, config.quant_cfg)
        max_len = config.max_len
        n_slots = config.n_slots
        prefill_chunk = config.prefill_chunk
        enable_metrics = config.enable_metrics
        # observability substrate (docs/serving.md "Observability"):
        # phase timers + counters in the registry, per-request lifecycle
        # events in the tracer, all surfaced through engine.metrics().
        # enable_metrics=False swaps in no-op instruments — the hot path
        # pays one attribute check per phase.
        self.metrics_registry = MetricsRegistry(enabled=enable_metrics)
        self.tracer = RequestTracer(capacity=config.trace_capacity,
                                    enabled=enable_metrics)
        self.max_len = max_len
        self.n_slots = n_slots
        self.model = Model(self.cfg)
        uniform = SlotKVCache.supports_blocks(self.model, max_len)
        # bucket padding is only sound for pure attention caches: the pad
        # tokens' cache writes are masked out by pos. Stateful caches
        # (mamba/rec) would absorb the pads into their recurrent state and
        # a window-truncated ring could roll real KV out in their favor.
        self.bucket_prompts = config.bucket_prompts and uniform
        self.scheduler = RequestScheduler(n_slots)
        if config.prefix_cache and uniform:
            bps = -(-max_len // config.block_size)
            extra = (2 * bps if config.n_cache_blocks is None
                     else config.n_cache_blocks)
            n_blocks = n_slots * bps + extra + 1  # +1: trash block
            self.cache = SlotKVCache(self.model, n_slots, max_len,
                                     config.cache_dtype,
                                     block_size=config.block_size,
                                     n_blocks=n_blocks)
            self.prefix_cache: Optional[RadixPrefixCache] = RadixPrefixCache(
                BlockPool(n_blocks, config.block_size))
            self._wire_scheduler()
            self._slot_meta: Dict[int, dict] = {}
        else:
            # recurrent / window-truncated caches: contiguous per-slot rows
            self.cache = SlotKVCache(self.model, n_slots, max_len,
                                     config.cache_dtype)
            self.prefix_cache = None
        if prefill_chunk is not None:
            if self.prefix_cache is None:
                raise ValueError(
                    "prefill_chunk requires the block-mode prefix cache "
                    "(uniform attention caches with prefix_cache=True)")
            # chunk boundaries must be block-aligned so each chunk commits
            # whole blocks into the arena as it lands
            bs = self.cache.block_size
            prefill_chunk = max(bs, -(-prefill_chunk // bs) * bs)
        self.prefill_chunk = prefill_chunk
        self.prefill_backlog = config.prefill_backlog
        self.fused_step = config.fused_step
        self._prefill_groups: collections.deque = collections.deque()
        # fused paged-attention decode: indexes the KV arena through the
        # block tables *inside* the attention kernel, so the per-step
        # gathered K/V copy of the reference path is never materialized.
        # "pallas" is the TPU kernel; "xla" is the scan fallback with the
        # same masking/accumulation contract for backends without Pallas
        # compile support; "pallas_interpret" exists for validation.
        if config.use_paged_kernel and self.prefix_cache is None:
            raise ValueError(
                "use_paged_kernel requires the block-mode prefix cache "
                "(uniform attention caches with prefix_cache=True)")
        if config.use_paged_kernel:
            self.paged_impl = config.paged_impl or (
                "pallas" if jax.default_backend() == "tpu" else "xla")
        else:
            self.paged_impl = None
        # analytical per-dispatch cost model (costmodel.py): every model
        # launch records predicted FLOPs / HBM bytes / SWIS shift-pass
        # cycles as cost.* counters + per-kind histograms. Built from the
        # live (possibly packed) params, so packed GEMMs are costed at
        # their bit-plane footprint.
        self.cost_model = CostModel.for_engine(self)
        self._step_no = 0
        self._prefill_flat = jax.jit(self.model.prefill_bucketed)
        self._prefill_sfx = jax.jit(self.model.prefill_chunk)
        self._decode = jax.jit(
            functools.partial(self.model.decode_step, paged=self.paged_impl),
            donate_argnums=(2,))
        self._mixed = jax.jit(
            functools.partial(self.model.mixed_step, paged=self.paged_impl),
            donate_argnums=(2,))
        # self-speculative decode: the draft model IS the target model —
        # same packed params, same arena — under a quant policy whose
        # keep_slices truncates every packed GEMM to the top draft_slices
        # bit-planes (draft_slices=None: full-precision draft, accept
        # rate 1.0 by construction). Draft steps are S=1 q_lens-masked
        # mixed launches; the verify launch scores all spec_k+1 positions
        # at full precision in one dispatch (Model.verify_step).
        self.spec_decode = config.spec_decode
        self.spec_k = config.spec_k
        if config.spec_decode:
            if self.prefix_cache is None:
                raise ValueError(
                    "spec_decode requires the block-mode prefix cache "
                    "(uniform attention caches with prefix_cache=True)")
            if config.draft_slices is None:
                self.draft_model = self.model
            else:
                total = total_slices(self.params)
                if not 1 <= config.draft_slices <= total:
                    raise ValueError(
                        f"draft_slices={config.draft_slices} out of range: "
                        f"the packed weights carry {total} bit-slices "
                        f"(1 <= draft_slices <= {total})")
                self.draft_model = Model(self.cfg.replace(
                    quant=dataclasses.replace(
                        self.cfg.quant, keep_slices=config.draft_slices)))
            self._draft = jax.jit(
                functools.partial(self.draft_model.mixed_step,
                                  paged=self.paged_impl),
                donate_argnums=(2,))
            self._verify = jax.jit(
                functools.partial(self.model.verify_step,
                                  paged=self.paged_impl),
                donate_argnums=(2,))
        self._dummy_key = jax.random.key(0)
        self._stat_prefill_tokens = 0
        self._stat_saved_tokens = 0
        self._stat_chunk_steps = 0

    # -- request API ----------------------------------------------------

    def submit(self, prompt, params: Optional[SamplingParams] = None,
               n_tokens: Optional[int] = None, temperature: float = 0.0,
               key=None, seed: Optional[int] = None, extra=None) -> int:
        """Enqueue a request: ``submit(prompt, SamplingParams(max_tokens,
        temperature=..., seed=...), extra=...)``. ``seed`` (or an explicit
        ``key``) makes the request's sampling reproducible; when neither
        is given, each request gets a distinct auto-key — independent
        clients must not draw identical streams. The legacy positional
        signature ``submit(prompt, n_tokens, temperature=..., key=...,
        seed=...)`` still works behind a ``DeprecationWarning``."""
        if isinstance(params, SamplingParams):
            if (n_tokens is not None or temperature or key is not None
                    or seed is not None):
                raise TypeError(
                    "legacy sampling kwargs (n_tokens/temperature/key/"
                    "seed) cannot be combined with SamplingParams")
        else:
            if isinstance(params, (int, np.integer)):
                if n_tokens is not None:
                    raise TypeError(
                        "got both a positional token budget and n_tokens")
                n_tokens = int(params)
            elif params is not None:
                raise TypeError(
                    f"submit() expects SamplingParams, got "
                    f"{type(params).__name__}")
            if n_tokens is None:
                raise TypeError(
                    "submit() needs a SamplingParams (or the deprecated "
                    "n_tokens kwarg)")
            warnings.warn(
                "submit(prompt, n_tokens, temperature=..., key=..., "
                "seed=...) is deprecated; pass "
                "submit(prompt, SamplingParams(max_tokens, ...))",
                DeprecationWarning, stacklevel=2)
            params = SamplingParams(
                max_tokens=int(n_tokens), temperature=temperature,
                seed=seed if key is None else None,
                key=key)
        return self._submit(prompt, params, extra)

    def _submit(self, prompt, sp: SamplingParams, extra=None) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size + sp.max_tokens > self.max_len:
            raise ValueError(
                f"prompt ({prompt.size}) + max_tokens ({sp.max_tokens}) "
                f"exceeds max_len ({self.max_len})")
        key = sp.key
        if key is None:
            if sp.seed is not None:
                key = jax.random.key(sp.seed)
            else:
                key = jax.random.fold_in(self._dummy_key,
                                         self.scheduler.next_rid())
        rid = self.scheduler.submit(prompt, sp.max_tokens, sp.temperature,
                                    key, extra)
        self.tracer.event(tr.SUBMIT, rid, prompt_len=int(prompt.size),
                          n_tokens=int(sp.max_tokens))
        return rid

    def step(self) -> List[Finished]:
        """One scheduler round: admit queued requests (unless the chunked
        backlog is full), run at most one chunk of prefill work, then one
        batched decode step over the DECODING slots. With ``fused_step``
        the chunk and the decode batch ride ONE ``mixed_step`` dispatch
        (``step.mixed_dispatch_s``) instead of two sequenced launches.

        Phase timers (``step.*_s`` histograms in ``metrics_registry``):
        admit, prefix_match, prefill_dispatch, chunk_advance,
        mixed_dispatch, decode_dispatch, device_sync, sample_host — plus
        ``step.total_s`` for the whole round. ``step.model_dispatches``
        counts forward launches (the fused win the dispatch-count test and
        the mixed_load bench gate measure)."""
        m = self.metrics_registry
        self.tracer.current_step = self._step_no
        with self._phase("step.total_s", "step"):
            if len(self._prefill_groups) < self.prefill_backlog:
                with self._phase("step.admit_s", "admit"):
                    admitted = self.scheduler.admit()
                if admitted:
                    for slot, st in admitted:
                        self.tracer.event(tr.ADMIT, st.req.rid, slot=slot)
                    self._prefill_admitted(admitted)
            decoded = False
            if self._prefill_groups:
                if self._prefill_groups[0].get("fused"):
                    # one dispatch services the chunk AND the decode batch
                    self._mixed_once()
                    decoded = True
                else:
                    with self._phase("step.chunk_advance_s",
                                     "chunk_advance"):
                        self._advance_chunk()
            if not decoded and self.scheduler.needs_decode():
                if self.spec_decode:
                    self._spec_once()
                else:
                    self._decode_once()
            finished = self.scheduler.pop_finished()
        for f in finished:
            self.tracer.event(tr.FINISH, f.rid, n_tokens=len(f.tokens))
        m.counter("step.count").inc()
        self._step_no += 1
        if m.enabled:
            # model-vs-measured utilization: bytes the cost model says the
            # issued dispatches should have moved, over measured step time
            total = m.histogram("step.total_s").total
            if total > 0.0:
                m.gauge("cost.hbm_bytes_per_s").set(
                    m.counter("cost.hbm_bytes").value / total)
                m.gauge("cost.flops_per_s").set(
                    m.counter("cost.flops").value / total)
        return finished

    def drain(self) -> Dict[int, np.ndarray]:
        """Step until idle. Returns {rid: prompt + generated tokens}."""
        out: Dict[int, np.ndarray] = {}
        while self.scheduler.pending():
            for f in self.step():
                out[f.rid] = np.concatenate([f.prompt, f.tokens])
        return out

    # -- static-batch compatibility wrapper -----------------------------

    def generate(self, prompt: np.ndarray, n_tokens: int,
                 extra: Optional[Dict[str, Any]] = None,
                 temperature: float = 0.0, seed: int = 0) -> np.ndarray:
        """Drop-in for ``DecodeEngine.generate``: prompt (B, S0) int32 ->
        (B, S0 + n_tokens). Row r samples with key fold_in(key(seed), r),
        matching the legacy engine token-for-token."""
        b, s0 = prompt.shape
        assert s0 + n_tokens <= self.max_len
        if self.scheduler.pending():
            raise RuntimeError(
                "generate() requires an idle engine (drain() would consume "
                "in-flight requests' results); use submit/step/drain")
        rng = jax.random.key(seed)
        rids = []
        for r in range(b):
            ex = ({k: np.asarray(v)[r] for k, v in extra.items()}
                  if extra else None)
            rids.append(self._submit(
                prompt[r],
                SamplingParams(max_tokens=n_tokens, temperature=temperature,
                               key=jax.random.fold_in(rng, r)),
                extra=ex))
        out = self.drain()
        return np.stack([out[rid] for rid in rids])

    def reset(self) -> None:
        """Return an idle engine to its post-construction state (empty
        queue, empty prefix cache, zeroed stats) *without* dropping the
        jit caches — benchmarks measure steady-state serving by running a
        warmup pass, resetting, and measuring the second pass on already
        compiled shapes. Stale arena K/V is left in place: every
        allocation path scrubs the blocks it takes over (whole-tree
        scatter unchunked, ``invalidate_blocks`` chunked) before their
        positions can enter a mask."""
        if self.scheduler.pending():
            raise RuntimeError("reset() requires an idle engine")
        self.scheduler = RequestScheduler(self.n_slots)
        self._prefill_groups.clear()
        if self.prefix_cache is not None:
            self.prefix_cache = RadixPrefixCache(
                BlockPool(self.cache.n_blocks, self.cache.block_size))
            self._wire_scheduler()
            self._slot_meta = {}
            for slot in range(self.n_slots):
                self.cache.clear_table(slot)
        self._stat_prefill_tokens = 0
        self._stat_saved_tokens = 0
        self._stat_chunk_steps = 0
        # back-to-back bench runs on one engine must start from clean
        # counters: fresh lifecycle data, zeroed phase timers
        self.metrics_registry.reset()
        self.tracer.reset()
        self._step_no = 0

    # -- observability ---------------------------------------------------

    def _phase(self, hist: str, span: str):
        """Phase timing context: one clock-pair feeds the ``hist``
        histogram AND (tracer enabled) a named span in the trace ring —
        the span nests under the enclosing ``step`` span by timestamp
        containment in the Chrome trace export."""
        if self.tracer.enabled:
            return self.tracer.span_timer(
                span, self.metrics_registry.histogram(hist))
        return self.metrics_registry.timer(hist)

    def _record_cost(self, cost) -> None:
        """Record one dispatch's predicted cost: global + per-kind
        ``cost.*`` counters, per-kind per-dispatch histograms."""
        m = self.metrics_registry
        if not m.enabled:
            return
        for field in _COST_FIELDS:
            v = getattr(cost, field)
            m.counter(f"cost.{field}").inc(v)
            m.counter(f"cost.{cost.kind}.{field}").inc(v)
            m.histogram(f"cost.{cost.kind}.{field}",
                        _COST_EDGES).observe(v)
        if cost.gathered_bytes:
            m.counter("cost.gathered_bytes").inc(cost.gathered_bytes)

    def metrics(self) -> Dict[str, Any]:
        """One unified observability snapshot: engine phase timers and
        counters, scheduler gauges, prefix-cache / BlockPool stats, and
        trace-ring health. ``prefix_stats()`` is a view of the
        ``prefix_cache`` section; metric names/units are tabulated in
        docs/serving.md ("Observability")."""
        snap = self.metrics_registry.snapshot()
        out: Dict[str, Any] = {
            "engine": {"n_slots": self.n_slots, "max_len": self.max_len,
                       "prefill_chunk": self.prefill_chunk,
                       "paged_impl": self.paged_impl,
                       "chunk_backlog_depth": len(self._prefill_groups),
                       "phases": snap["histograms"],
                       "counters": snap["counters"],
                       "gauges": snap["gauges"],
                       "cost_model": self.cost_model.summary()},
            "scheduler": self.scheduler.gauges(),
            "prefix_cache": self._prefix_cache_section(),
            "trace": {"events": len(self.tracer),
                      "dropped": self.tracer.dropped,
                      "capacity": self.tracer.capacity,
                      "spans": len(self.tracer.spans()),
                      "dropped_spans": self.tracer.dropped_spans},
        }
        if self.prefix_cache is not None:
            out["block_pool"] = self.prefix_cache.pool.occupancy()
        return out

    def _prefix_cache_section(self) -> Dict[str, Any]:
        if self.prefix_cache is None:
            return {"enabled": False,
                    "prefill_tokens": self._stat_prefill_tokens,
                    "saved_tokens": 0, "prefill_chunk": None,
                    "prefill_chunk_steps": 0}
        out = self.prefix_cache.stats()
        out.update(enabled=True, block_size=self.cache.block_size,
                   prefill_tokens=self._stat_prefill_tokens,
                   saved_tokens=self._stat_saved_tokens,
                   hit_tokens=self._stat_saved_tokens,
                   prefill_chunk=self.prefill_chunk,
                   prefill_chunk_steps=self._stat_chunk_steps)
        return out

    def prefix_stats(self) -> Dict[str, Any]:
        """Prefix-cache health: hit rate, tokens saved vs computed, block
        commits/evictions, arena occupancy. Delegates to
        :meth:`metrics` — same dict as ``metrics()['prefix_cache']``."""
        return self._prefix_cache_section()

    # -- internals ------------------------------------------------------

    def _wire_scheduler(self) -> None:
        self.scheduler.on_release = self._release_slot
        self.scheduler.admission_priority = self._hit_score

    # -- internals ------------------------------------------------------

    def _hit_score(self, req) -> int:
        """Cache-aware admission: expected cached-prefix tokens (0 for
        requests with extra inputs, which never share prefixes)."""
        if req.extra:
            return 0
        bs = self.cache.block_size
        return bs * self.prefix_cache.peek_blocks(
            req.prompt, max_blocks=(len(req.prompt) - 1) // bs)

    def _bucket(self, s: int, prefix_len: int) -> int:
        """Pad a (suffix) prefill length up to a power-of-two bucket so the
        jit cache holds one entry per bucket, not one per distinct prompt
        length. Clamped to the cache capacity past the prefix."""
        cap = (self.cache.eff_len if self.prefix_cache is not None
               else self.max_len) - prefix_len
        if not self.bucket_prompts:
            return s
        return min(max(8, 1 << max(s - 1, 0).bit_length()), cap)

    def _assign_blocks(self, admitted):
        """Block-mode admission: match each request's prompt against the
        radix trie, reference the cached prefix blocks, and allocate owned
        blocks for the rest (evicting unreferenced LRU blocks on pressure).
        Requests the pool cannot cover yet go back to the queue."""
        pool = self.prefix_cache.pool
        bs = self.cache.block_size
        ok, failed = [], []
        for slot, st in admitted:
            rid = st.req.rid
            req = st.req
            s0 = len(req.prompt)
            need = -(-(s0 + req.n_tokens) // bs)
            # cap the match so at least one suffix token runs through the
            # model — its logits seed generation
            matched = ([] if req.extra else self.prefix_cache.match(
                req.prompt, max_blocks=(s0 - 1) // bs))
            pool.incref(matched)
            own = need - len(matched)
            if pool.n_free() < own:
                self.prefix_cache.evict(own - pool.n_free())
            ids = pool.alloc(own)
            if ids is None:
                self.prefix_cache.release(matched)
                failed.append(slot)
                self.tracer.event(tr.UNADMIT, rid, slot=slot,
                                  blocks_needed=own,
                                  blocks_free=pool.n_free())
                continue
            if not req.extra:
                self.prefix_cache.count_lookup(matched)
            if matched:
                self.tracer.event(tr.PREFIX_HIT, rid, slot=slot,
                                  blocks=len(matched),
                                  tokens=len(matched) * bs)
            pool.incref(ids)
            if self.prefill_chunk is None:
                self.cache.set_table(slot, matched + ids)
            # chunked mode: the table stays on the trash block until the
            # last chunk lands — a PREFILLING slot's dummy decode row must
            # not write into (possibly shared) live blocks
            self._slot_meta[slot] = {"matched": matched, "owned": ids,
                                     "need": need,
                                     "prefix_blocks": len(matched)}
            self._stat_saved_tokens += len(matched) * bs
            ok.append((slot, st))
        for slot in reversed(failed):  # appendleft: reverse keeps FIFO
            self.scheduler.unadmit(slot)
        return ok

    def _release_slot(self, slot: int, st) -> None:
        """Scheduler release hook (block mode): commit the request's full
        token blocks into the trie, drop its block references, and point
        the freed slot's table at the trash block so dummy decode writes
        cannot touch live blocks."""
        meta = self._slot_meta.pop(slot, None)
        if meta is None:
            return
        if not st.req.extra:
            # cache rows hold K/V for prompt + all *fed-back* tokens (the
            # final sampled token never re-enters the model)
            seq = np.concatenate(
                [st.req.prompt, np.asarray(st.tokens[:-1], np.int32)])
            n_commit = min(len(seq) // self.cache.block_size, meta["need"])
            self.prefix_cache.commit(
                seq, self.cache.block_tables[slot, :n_commit].tolist())
        self.prefix_cache.release(meta["matched"] + meta["owned"])
        self.cache.clear_table(slot)

    def _prefill_admitted(self, admitted) -> None:
        # Group by (prefix length, bucketed suffix length, extra-input
        # signature — so requests with and without e.g. vlm patches never
        # share a batch): one batched prefill per group keeps the jit
        # shapes bounded and makes lockstep admission numerically identical
        # to a static-batch prefill.
        if self.prefix_cache is not None:
            with self._phase("step.prefix_match_s", "prefix_match"):
                admitted = self._assign_blocks(admitted)
            if self.prefill_chunk is not None:
                with self._phase("step.chunk_advance_s", "chunk_advance"):
                    self._stage_chunked(admitted)
                return
        with self._phase("step.prefill_dispatch_s", "prefill_dispatch"):
            self._run_prefill(admitted)

    def _run_prefill(self, admitted) -> None:
        groups: Dict[Any, list] = {}
        for slot, st in admitted:
            ex = st.req.extra
            sig = (tuple(sorted((k, np.shape(v)) for k, v in ex.items()))
                   if ex else None)
            pb = (self._slot_meta[slot]["prefix_blocks"]
                  if self.prefix_cache is not None else 0)
            p_len = pb * (self.cache.block_size or 0)
            s_real = len(st.req.prompt) - p_len
            groups.setdefault((p_len, self._bucket(s_real, p_len), sig),
                              []).append((slot, st))
        for (p_len, s_pad, _), group in groups.items():
            g = len(group)
            toks = np.zeros((g, s_pad), np.int32)
            lasts = np.empty(g, np.int32)
            for i, (_, st) in enumerate(group):
                sfx = st.req.prompt[p_len:]
                toks[i, :len(sfx)] = sfx
                lasts[i] = len(sfx) - 1
            batch = {"tokens": jnp.asarray(toks)}
            extras = [st.req.extra for _, st in group]
            if extras[0]:
                for k in extras[0]:
                    batch[k] = jnp.asarray(
                        np.stack([ex[k] for ex in extras]))
            last_idx = jnp.asarray(lasts)
            self._stat_prefill_tokens += int(lasts.sum()) + g
            self.metrics_registry.counter("step.model_dispatches").inc()
            self._record_cost(self.cost_model.prefill(g, s_pad))
            if self.prefix_cache is not None:
                meta = [self._slot_meta[slot] for slot, _ in group]
                cache = self.cache.prefix_tree(
                    [m["matched"] for m in meta], p_len)
                if p_len:
                    logits, cache = self._prefill_sfx(
                        self.params, batch, cache, jnp.int32(p_len),
                        last_idx)
                else:
                    logits, cache = self._prefill_flat(
                        self.params, batch, cache, last_idx)
                for i, (slot, st) in enumerate(group):
                    self.cache.scatter_row(
                        cache, i, meta[i]["owned"],
                        meta[i]["prefix_blocks"],
                        len(st.req.prompt) - p_len)
            else:
                cache = self.cache.fresh(g)
                logits, cache = self._prefill_flat(
                    self.params, batch, cache, last_idx)
                cache = self.cache.mask_pos_tail(
                    cache, [len(st.req.prompt) for _, st in group])
                self.cache.write_slots(cache, [slot for slot, _ in group])
            keys = jnp.stack([st.req.key for _, st in group])
            temps = jnp.asarray(
                [st.req.temperature for _, st in group], jnp.float32)
            steps = jnp.zeros(g, jnp.int32)
            first = np.asarray(sample_step(logits, keys, steps, temps))
            for (slot, st), tok in zip(group, first):
                self.tracer.event(tr.FIRST_TOKEN, st.req.rid, slot=slot)
                self.scheduler.record_prefill(slot, tok)

    def _stage_chunked(self, admitted) -> None:
        """Stage admitted requests as chunk-prefill groups (no model work
        yet — ``_advance_chunk`` runs one chunk per engine step). Grouping
        key: (prefix length, chunk count, bucketed final-chunk length,
        extra-input signature), so every row of a group advances through
        the same chunk geometry in lockstep and one jit'd call per step
        covers the whole group. The working tree is gathered once here
        (cached prefix only) and carried across steps; chunk boundaries
        are block-aligned, so each chunk commits whole blocks as it
        lands."""
        chunk = self.prefill_chunk
        bs = self.cache.block_size
        groups: Dict[Any, list] = {}
        for slot, st in admitted:
            ex = st.req.extra
            sig = (tuple(sorted((k, np.shape(v)) for k, v in ex.items()))
                   if ex else None)
            p_len = self._slot_meta[slot]["prefix_blocks"] * bs
            s_real = len(st.req.prompt) - p_len
            n_chunks = -(-s_real // chunk)
            tail = self._bucket(s_real - (n_chunks - 1) * chunk,
                                p_len + (n_chunks - 1) * chunk)
            groups.setdefault((p_len, n_chunks, tail, sig),
                              []).append((slot, st))
        for (p_len, n_chunks, tail, sig), members in groups.items():
            g = len(members)
            s_pad = (n_chunks - 1) * chunk + tail
            toks = np.zeros((g, s_pad), np.int32)
            lasts = np.empty(g, np.int32)
            metas = []
            for i, (slot, st) in enumerate(members):
                meta = self._slot_meta[slot]
                metas.append(meta)
                sfx = st.req.prompt[p_len:]
                toks[i, :len(sfx)] = sfx
                lasts[i] = len(sfx) - (n_chunks - 1) * chunk - 1
            # owned blocks commit chunk by chunk, so scrub their stale pos
            # up front (one batched call): the not-yet-reached tail must
            # never enter an attention mask (unchunked mode scrubs by
            # scattering the whole fresh working tree instead)
            self.cache.invalidate_blocks(
                [b for m in metas for b in m["owned"]])
            grp = {
                "members": members, "metas": metas, "toks": toks,
                "lasts": lasts, "p_len": p_len, "n_chunks": n_chunks,
                "tail": tail, "done": 0, "tree": None,
                "extra": [st.req.extra for _, st in members]}
            if self.fused_step and sig is None:
                # fused groups need no working tree at all: each chunk
                # commits straight into the arena through the group's
                # per-row block tables inside the mixed launch (extra-input
                # groups fall back to the separate path — mixed batches
                # carry no per-row side inputs)
                grp["fused"] = True
                grp["tables"] = self.cache.group_tables(
                    [m["matched"] + m["owned"] for m in metas])
            else:
                # the working tree only needs committed + padded-suffix
                # rows, not the slot's full capacity — chunk attention
                # stays O(chunk * committed) instead of O(chunk *
                # eff_len). Rounded up to a pow2 (then a block multiple)
                # so distinct prefix-hit lengths share jit cache entries
                # instead of compiling per p_len.
                need = p_len + s_pad
                length = -(-(1 << max(need - 1, 0).bit_length()) // bs) * bs
                length = min(self.cache.eff_len, max(length, bs))
                grp["tree"] = self.cache.prefix_tree(
                    [m["matched"] for m in metas], p_len, length=length)
                grp["tree_len"] = length  # chunk cost: attended positions
            self._prefill_groups.append(grp)

    def _advance_chunk(self) -> None:
        """Run one chunk of prefill for the head group, round-robin across
        in-flight groups: prefill the chunk's tokens at the group's
        committed offset, attend over everything committed so far, and
        scatter the chunk's blocks into the arena. On the final chunk,
        sample each row's first token and flip its slot to DECODING (its
        block table goes live now, never earlier)."""
        grp = self._prefill_groups[0]
        chunk = self.prefill_chunk
        bs = self.cache.block_size
        k = grp["done"]
        final = k == grp["n_chunks"] - 1
        s_chunk = grp["tail"] if final else chunk
        lo = k * chunk
        g = len(grp["members"])
        batch = {"tokens": jnp.asarray(grp["toks"][:, lo:lo + s_chunk])}
        extras = grp["extra"]
        if extras[0]:
            for key in extras[0]:
                batch[key] = jnp.asarray(
                    np.stack([ex[key] for ex in extras]))
        last_idx = (jnp.asarray(grp["lasts"]) if final
                    else jnp.full((g,), s_chunk - 1, jnp.int32))
        committed = grp["p_len"] + lo
        self._stat_chunk_steps += 1
        self.metrics_registry.counter("step.model_dispatches").inc()
        self._record_cost(self.cost_model.chunk(g, s_chunk,
                                                grp["tree_len"]))
        if committed == 0:
            # first chunk of an uncached prompt: nothing committed, the
            # chunk attends over its own K/V like a whole-prompt prefill
            logits, tree = self._prefill_flat(self.params, batch,
                                              grp["tree"], last_idx)
        else:
            logits, tree = self._prefill_sfx(self.params, batch,
                                             grp["tree"],
                                             jnp.int32(committed), last_idx)
        grp["tree"] = tree
        grp["done"] = k + 1
        # append this chunk at its offset into each row's owned blocks
        b0 = lo // bs
        for i, (slot, st) in enumerate(grp["members"]):
            meta = grp["metas"][i]
            n_valid = min(len(st.req.prompt) - grp["p_len"] - lo, s_chunk)
            nb = -(-n_valid // bs)
            self.cache.scatter_row(tree, i, meta["owned"][b0:b0 + nb],
                                   meta["prefix_blocks"] + b0, n_valid)
            self.tracer.event(tr.PREFILL_CHUNK, st.req.rid, slot=slot,
                              index=k, n_chunks=grp["n_chunks"],
                              tokens=int(n_valid))
        if not final:
            # round-robin across in-flight groups: a 1-chunk group (short
            # prompt) admitted behind a long prefill is serviced on the
            # very next step instead of waiting out every long chunk
            self._prefill_groups.rotate(-1)
            return
        self._prefill_groups.popleft()
        for i, (slot, st) in enumerate(grp["members"]):
            meta = grp["metas"][i]
            self.cache.set_table(slot, meta["matched"] + meta["owned"])
            self._stat_prefill_tokens += len(st.req.prompt) - grp["p_len"]
        keys = jnp.stack([st.req.key for _, st in grp["members"]])
        temps = jnp.asarray(
            [st.req.temperature for _, st in grp["members"]], jnp.float32)
        first = np.asarray(sample_step(logits, keys,
                                       jnp.zeros(g, jnp.int32), temps))
        for (slot, st), tok in zip(grp["members"], first):
            self.tracer.event(tr.FIRST_TOKEN, st.req.rid, slot=slot)
            self.scheduler.record_prefill(slot, tok)

    def _mixed_once(self) -> None:
        """Service the head fused chunk group AND the whole decode batch
        in ONE ``mixed_step`` dispatch. Batch layout: rows [0, n_slots)
        are the per-slot decode rows (token in column 0, ``q_lens`` 1 for
        DECODING slots, 0 for free/PREFILLING slots — their rows are
        fully masked no-ops), rows [n_slots, n_slots + g) are the chunk
        group's rows (``q_lens`` = real tokens this chunk, block tables =
        matched prefix + owned blocks). Every row commits its valid K/V
        through its own table inside the launch, so the host-side
        ``scatter_row`` of the separate path never runs; invalid tokens
        route to the trash block. Per-request token streams are identical
        to the separate path — a slot whose final chunk lands this step
        simply joins the decode batch next step, same as before."""
        m = self.metrics_registry
        grp = self._prefill_groups[0]
        chunk = self.prefill_chunk
        k = grp["done"]
        final = k == grp["n_chunks"] - 1
        s_chunk = grp["tail"] if final else chunk
        lo = k * chunk
        g = len(grp["members"])
        n = self.n_slots
        toks, idxs, steps, temps, keys = self.scheduler.decode_batch(
            self._dummy_key)
        decoding = self.scheduler.decoding_slots()
        live = [(s, self.scheduler.slots[s].req.rid, int(steps[s]))
                for s in decoding] if self.tracer.enabled else []
        btoks = np.zeros((n + g, s_chunk), np.int32)
        btoks[:n, 0] = toks
        btoks[n:] = grp["toks"][:, lo:lo + s_chunk]
        q_lens = np.zeros(n + g, np.int32)
        q_lens[decoding] = 1
        start = np.zeros(n + g, np.int32)
        start[:n] = idxs
        start[n:] = grp["p_len"] + lo
        last_idx = np.zeros(n + g, np.int32)
        last_idx[n:] = grp["lasts"] if final else s_chunk - 1
        n_valids = []
        for i, (slot, st) in enumerate(grp["members"]):
            nv = min(len(st.req.prompt) - grp["p_len"] - lo, s_chunk)
            n_valids.append(nv)
            q_lens[n + i] = nv
        tables = np.concatenate([self.cache.block_tables, grp["tables"]])
        self._stat_chunk_steps += 1
        m.counter("step.model_dispatches").inc()
        self._record_cost(self.cost_model.mixed(n + g, s_chunk))
        with self._phase("step.mixed_dispatch_s", "mixed_dispatch"):
            logits, tree = self._mixed(
                self.params, {"tokens": jnp.asarray(btoks)},
                self.cache.tree, jnp.asarray(start), jnp.asarray(q_lens),
                jnp.asarray(last_idx), jnp.asarray(tables))
            self.cache.tree = tree
        if m.enabled:
            with self._phase("step.device_sync_s", "device_sync"):
                jax.block_until_ready(logits)
        all_keys = list(keys) + [st.req.key for _, st in grp["members"]]
        all_steps = np.concatenate([steps, np.zeros(g, np.int32)])
        all_temps = np.concatenate(
            [temps, np.asarray([st.req.temperature
                                for _, st in grp["members"]], np.float32)])
        with self._phase("step.sample_host_s", "sample_host"):
            nxt = np.asarray(sample_step(
                logits, jnp.stack(all_keys), jnp.asarray(all_steps),
                jnp.asarray(all_temps)))
            self.scheduler.record_decode(nxt[:n])
        for slot, rid, step in live:
            self.tracer.event(tr.DECODE_STEP, rid, slot=slot, step=step)
        grp["done"] = k + 1
        for i, (slot, st) in enumerate(grp["members"]):
            self.tracer.event(tr.PREFILL_CHUNK, st.req.rid, slot=slot,
                              index=k, n_chunks=grp["n_chunks"],
                              tokens=int(n_valids[i]))
        if not final:
            self._prefill_groups.rotate(-1)
            return
        self._prefill_groups.popleft()
        for i, (slot, st) in enumerate(grp["members"]):
            meta = grp["metas"][i]
            self.cache.set_table(slot, meta["matched"] + meta["owned"])
            self._stat_prefill_tokens += len(st.req.prompt) - grp["p_len"]
            self.tracer.event(tr.FIRST_TOKEN, st.req.rid, slot=slot)
            self.scheduler.record_prefill(slot, int(nxt[n + i]))

    def _decode_once(self) -> None:
        m = self.metrics_registry
        toks, idxs, steps, temps, keys = self.scheduler.decode_batch(
            self._dummy_key)
        # (slot, rid, step) of the live rows — captured before
        # record_decode frees finished slots
        live = [(s, self.scheduler.slots[s].req.rid, int(steps[s]))
                for s in self.scheduler.decoding_slots()] \
            if self.tracer.enabled else []
        m.counter("step.model_dispatches").inc()
        self._record_cost(self.cost_model.decode(self.n_slots))
        with self._phase("step.decode_dispatch_s", "decode_dispatch"):
            if self.prefix_cache is not None:
                logits, tree = self._decode(
                    self.params, jnp.asarray(toks)[:, None],
                    self.cache.tree, jnp.asarray(idxs),
                    self.cache.tables_device())
            else:
                logits, tree = self._decode(
                    self.params, jnp.asarray(toks)[:, None],
                    self.cache.tree, jnp.asarray(idxs))
            self.cache.tree = tree
        if m.enabled:
            # split device wait from host-side sampling: logits are about
            # to be consumed either way, so the sync is not extra work
            with self._phase("step.device_sync_s", "device_sync"):
                jax.block_until_ready(logits)
        with self._phase("step.sample_host_s", "sample_host"):
            nxt = sample_step(logits, jnp.stack(keys), jnp.asarray(steps),
                              jnp.asarray(temps))
            self.scheduler.record_decode(np.asarray(nxt))
        for slot, rid, step in live:
            self.tracer.event(tr.DECODE_STEP, rid, slot=slot, step=step)

    def _spec_once(self) -> None:
        """One self-speculative decode round over the DECODING slots.

        Draft: ``k_max`` sequential S=1 launches of the truncated-slice
        draft model, each proposing the next token per row through the
        SAME seeded sampler (key, step) the verify targets use — so a
        draft that produces the full-precision logits reproduces the
        target token exactly. Rows draft only up to their budget
        ``k_rows[r] = min(spec_k, remaining - 1)`` (the verify's bonus
        token is the +1); beyond it their ``q_lens`` drops to 0 and their
        writes route to the trash block.

        Verify: ONE full-precision launch feeds ``[t0, d1..dk]`` per row
        through :meth:`Model.verify_step`, scoring all ``k_max + 1``
        positions and rewriting every draft-fed arena position at full
        precision (which is the whole KV rollback story — see
        kv_cache.py). Targets for all positions come from one flattened
        ``sample_step``; row r accepts drafts while ``d[j] ==
        target[j-1]`` and always emits at least target[0] — the token
        plain decode would have produced, hence token-exactness for every
        accept pattern.
        """
        m = self.metrics_registry
        toks, idxs, steps, temps, keys = self.scheduler.decode_batch(
            self._dummy_key)
        decoding = self.scheduler.decoding_slots()
        n = self.n_slots
        k_rows = np.zeros(n, np.int32)
        for s in decoding:
            st = self.scheduler.slots[s]
            k_rows[s] = min(self.spec_k, st.req.n_tokens - st.n_gen - 1)
        k_max = int(k_rows.max(initial=0))
        if k_max == 0:
            # every live row is one token from its budget: speculation
            # degenerates to plain decode, so run exactly that
            self._decode_once()
            return
        live = [(s, self.scheduler.slots[s].req.rid, int(steps[s]))
                for s in decoding] if self.tracer.enabled else []
        tables = self.cache.tables_device()
        keys_dev = jnp.stack(keys)
        zeros = jnp.zeros(n, jnp.int32)
        draft_toks = np.zeros((n, k_max), np.int32)
        cur = toks
        m.counter("spec.steps").inc()
        with self._phase("spec.draft_s", "spec_draft"):
            for j in range(k_max):
                q1 = (k_rows > j).astype(np.int32)
                m.counter("step.model_dispatches").inc()
                self._record_cost(self.cost_model.draft(
                    n, keep_slices=self.config.draft_slices))
                logits, tree = self._draft(
                    self.params, {"tokens": jnp.asarray(cur)[:, None]},
                    self.cache.tree, jnp.asarray(idxs + j),
                    jnp.asarray(q1), zeros, tables)
                self.cache.tree = tree
                d = np.asarray(sample_step(logits, keys_dev,
                                           jnp.asarray(steps + j),
                                           jnp.asarray(temps)))
                draft_toks[:, j] = d
                cur = d
        s_v = k_max + 1
        btoks = np.zeros((n, s_v), np.int32)
        btoks[:, 0] = toks
        btoks[:, 1:] = draft_toks
        q_lens = np.zeros(n, np.int32)
        for s in decoding:
            q_lens[s] = k_rows[s] + 1
        m.counter("step.model_dispatches").inc()
        self._record_cost(self.cost_model.verify(n, s_v))
        with self._phase("spec.verify_s", "spec_verify"):
            logits, tree = self._verify(
                self.params, {"tokens": jnp.asarray(btoks)},
                self.cache.tree, jnp.asarray(idxs), jnp.asarray(q_lens),
                tables)
            self.cache.tree = tree
        if m.enabled:
            with self._phase("step.device_sync_s", "device_sync"):
                jax.block_until_ready(logits)
        with self._phase("step.sample_host_s", "sample_host"):
            # one flattened sample over all (row, position) pairs: entry
            # (r, j) draws with (keys[r], steps[r] + j) — exactly the
            # (key, step) plain decode would use for that token index
            flat_keys = jnp.stack([k for k in keys for _ in range(s_v)])
            flat_steps = (steps[:, None]
                          + np.arange(s_v, dtype=np.int32)[None, :])
            targets = np.asarray(sample_step(
                logits.reshape(n * s_v, -1), flat_keys,
                jnp.asarray(flat_steps.reshape(-1)),
                jnp.asarray(np.repeat(temps, s_v)))).reshape(n, s_v)
        accepted: Dict[int, np.ndarray] = {}
        for s in decoding:
            k_r = int(k_rows[s])
            a = 0
            while a < k_r and draft_toks[s, a] == targets[s, a]:
                a += 1
            accepted[s] = targets[s, :a + 1]
        m.counter("spec.proposed").inc(int(k_rows.sum()))
        m.counter("spec.accepted").inc(
            sum(len(v) - 1 for v in accepted.values()))
        m.counter("spec.tokens").inc(
            sum(len(v) for v in accepted.values()))
        self.scheduler.record_spec(accepted)
        for slot, rid, step in live:
            got = len(accepted[slot])
            self.tracer.event(tr.SPEC_ACCEPT, rid, slot=slot,
                              proposed=int(k_rows[slot]),
                              accepted=got - 1, tokens=got)
            for j in range(got):
                self.tracer.event(tr.DECODE_STEP, rid, slot=slot,
                                  step=step + j)


# ---------------------------------------------------------------------------
# Legacy static-batch engine (parity oracle)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DecodeEngine:
    """Static-batch decode: prefill + lockstep decode over a ring KV cache,
    fresh cache per ``generate`` call. Superseded by
    :class:`ContinuousBatchingEngine` on the serving path; retained as the
    reference implementation the parity tests pin the new engine against."""

    cfg: ArchConfig
    params: Any
    max_len: int = 256
    batch: int = 1
    packed: bool = False
    quant_cfg: Optional[QuantConfig] = None
    cache_dtype: Any = jnp.float32

    def __post_init__(self):
        self.cfg, self.params, self.pack_stats = _maybe_pack(
            self.cfg, self.params, self.packed, self.quant_cfg)
        self.model = Model(self.cfg)
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode_step, donate_argnums=(2,))

    def new_cache(self):
        tree = self.model.build_cache(self.batch, self.max_len,
                                      self.cache_dtype)
        return pp.init_params(tree, jax.random.key(0))

    def generate(self, prompt: np.ndarray, n_tokens: int,
                 extra: Optional[Dict[str, Any]] = None,
                 temperature: float = 0.0, seed: int = 0) -> np.ndarray:
        """prompt: (B, S0) int32. Returns (B, S0 + n_tokens)."""
        b, s0 = prompt.shape
        assert b == self.batch and s0 + n_tokens <= self.max_len
        cache = self.new_cache()
        batch = {"tokens": jnp.asarray(prompt, jnp.int32)}
        if extra:
            batch.update({k: jnp.asarray(v) for k, v in extra.items()})
        logits, cache = self._prefill(self.params, batch, cache)
        rng = jax.random.key(seed)
        keys = _row_keys(rng, b)
        temps = jnp.full((b,), temperature, jnp.float32)
        out = [jnp.asarray(prompt, jnp.int32)]
        tok = self._sample(logits, keys, temps, 0)
        for i in range(n_tokens):
            out.append(tok)
            if i == n_tokens - 1:
                break
            logits, cache = self._decode(self.params, tok, cache,
                                         jnp.int32(s0 + i))
            tok = self._sample(logits, keys, temps, i + 1)
        return np.asarray(jnp.concatenate(out, axis=1))

    @staticmethod
    def _sample(logits, keys, temps, i):
        steps = jnp.full((logits.shape[0],), i, jnp.int32)
        return sample_step(logits, keys, steps, temps)[:, None]
