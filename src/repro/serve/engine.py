"""Serve engines over SWIS-packed weights.

Two engines share the same model, packing path, and seeded sampler:

* :class:`ContinuousBatchingEngine` — the serving hot path. A
  :class:`~repro.serve.scheduler.RequestScheduler` admits requests from a
  queue into free slots of a :class:`~repro.serve.kv_cache.SlotKVCache`;
  admitted requests prefill into their slot (grouped by prompt length)
  while the other slots keep decoding, one batched per-slot decode step at
  a time (``submit`` / ``step`` / ``drain``). With ``packed=True`` the
  whole hot path runs on SWIS bit-plane weights (``pack_tree``) — HBM
  weight traffic per decode step is the compressed bytes, the paper's
  serving-side win.

* :class:`DecodeEngine` — the legacy static-batch engine (one lockstep
  batch, fresh cache per call). Kept as the parity oracle:
  ``ContinuousBatchingEngine.generate`` reproduces its greedy tokens
  exactly, and its seeded-temperature tokens exactly too because both
  engines sample through :func:`sample_step` with identical per-row keys.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.swis import QuantConfig
from repro.models import params as pp
from repro.models.model import Model
from repro.serve.kv_cache import SlotKVCache
from repro.serve.quantized import pack_tree
from repro.serve.scheduler import Finished, RequestScheduler


@jax.jit
def sample_step(logits, keys, steps, temps):
    """Seeded per-row sampling shared by both engines.

    Row r draws from ``categorical(fold_in(keys[r], steps[r]),
    logits[r] / temps[r])`` (argmax when temps[r] <= 0). Because the key is
    per-row, a request's tokens depend only on its own (key, step, logits)
    — not on batch size, slot position, or who else is in flight.
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def one(key, step, row, t):
        k = jax.random.fold_in(key, step)
        return jax.random.categorical(
            k, row / jnp.maximum(t, 1e-6)).astype(jnp.int32)

    sampled = jax.vmap(one)(keys, steps, logits, temps)
    return jnp.where(temps <= 0.0, greedy, sampled)


def _row_keys(rng, b: int):
    """Per-row sampling keys for a lockstep batch: row r gets
    fold_in(rng, r) — the same derivation ``generate()`` compat uses."""
    return jax.vmap(lambda r: jax.random.fold_in(rng, r))(
        jnp.arange(b, dtype=jnp.uint32))


def _maybe_pack(cfg: ArchConfig, params, packed: bool,
                quant_cfg: Optional[QuantConfig]):
    """Common packing path: returns (cfg, params, pack_stats)."""
    if not packed:
        return cfg, params, None
    qcfg = quant_cfg or cfg.quant.cfg
    params, stats = pack_tree(params, qcfg)
    # record the pack method so dense()/moe dispatch the right
    # (consecutive vs sparse) unpack semantics
    from repro.configs.base import QuantPolicy

    return cfg.replace(quant=QuantPolicy(cfg=qcfg, mode="off")), params, stats


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------


class ContinuousBatchingEngine:
    """Step-driven serve engine: requests join mid-flight.

    API: ``submit(prompt_1d, n_tokens, ...) -> rid``; ``step()`` runs one
    scheduler round (admit + prefill new slots, one batched decode step)
    and returns the requests that finished; ``drain()`` steps until idle.
    ``generate`` is the drop-in static-batch compatibility wrapper.
    """

    def __init__(self, cfg: ArchConfig, params: Any, max_len: int = 256,
                 n_slots: int = 4, packed: bool = False,
                 quant_cfg: Optional[QuantConfig] = None,
                 cache_dtype: Any = jnp.float32):
        self.cfg, self.params, self.pack_stats = _maybe_pack(
            cfg, params, packed, quant_cfg)
        self.max_len = max_len
        self.n_slots = n_slots
        self.model = Model(self.cfg)
        self.cache = SlotKVCache(self.model, n_slots, max_len, cache_dtype)
        self.scheduler = RequestScheduler(n_slots)
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode_step, donate_argnums=(2,))
        self._dummy_key = jax.random.key(0)

    # -- request API ----------------------------------------------------

    def submit(self, prompt, n_tokens: int, temperature: float = 0.0,
               key=None, seed: Optional[int] = None, extra=None) -> int:
        """``seed`` (or an explicit ``key``) makes a request's sampling
        reproducible. When neither is given, each request gets a distinct
        auto-key — independent clients must not draw identical streams."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if n_tokens < 0:
            raise ValueError(f"n_tokens must be >= 0, got {n_tokens}")
        if prompt.size + n_tokens > self.max_len:
            raise ValueError(
                f"prompt ({prompt.size}) + n_tokens ({n_tokens}) exceeds "
                f"max_len ({self.max_len})")
        if key is None:
            if seed is not None:
                key = jax.random.key(seed)
            else:
                key = jax.random.fold_in(self._dummy_key,
                                         self.scheduler.next_rid())
        return self.scheduler.submit(prompt, n_tokens, temperature, key,
                                     extra)

    def step(self) -> List[Finished]:
        """Admit + prefill newly queued requests, then one decode step."""
        admitted = self.scheduler.admit()
        if admitted:
            self._prefill_admitted(admitted)
        if self.scheduler.needs_decode():
            self._decode_once()
        return self.scheduler.pop_finished()

    def drain(self) -> Dict[int, np.ndarray]:
        """Step until idle. Returns {rid: prompt + generated tokens}."""
        out: Dict[int, np.ndarray] = {}
        while self.scheduler.pending():
            for f in self.step():
                out[f.rid] = np.concatenate([f.prompt, f.tokens])
        return out

    # -- static-batch compatibility wrapper -----------------------------

    def generate(self, prompt: np.ndarray, n_tokens: int,
                 extra: Optional[Dict[str, Any]] = None,
                 temperature: float = 0.0, seed: int = 0) -> np.ndarray:
        """Drop-in for ``DecodeEngine.generate``: prompt (B, S0) int32 ->
        (B, S0 + n_tokens). Row r samples with key fold_in(key(seed), r),
        matching the legacy engine token-for-token."""
        b, s0 = prompt.shape
        assert s0 + n_tokens <= self.max_len
        if self.scheduler.pending():
            raise RuntimeError(
                "generate() requires an idle engine (drain() would consume "
                "in-flight requests' results); use submit/step/drain")
        rng = jax.random.key(seed)
        rids = []
        for r in range(b):
            ex = ({k: np.asarray(v)[r] for k, v in extra.items()}
                  if extra else None)
            rids.append(self.submit(
                prompt[r], n_tokens, temperature=temperature,
                key=jax.random.fold_in(rng, r), extra=ex))
        out = self.drain()
        return np.stack([out[rid] for rid in rids])

    # -- internals ------------------------------------------------------

    def _prefill_admitted(self, admitted) -> None:
        # Group by prompt length (and extra-input signature, so requests
        # with and without e.g. vlm patches never share a batch): one
        # batched prefill per group keeps the jit shapes bounded and makes
        # lockstep admission numerically identical to a static-batch
        # prefill.
        groups: Dict[Any, list] = {}
        for slot, st in admitted:
            ex = st.req.extra
            sig = (tuple(sorted((k, np.shape(v)) for k, v in ex.items()))
                   if ex else None)
            groups.setdefault((len(st.req.prompt), sig), []).append(
                (slot, st))
        for _, group in groups.items():
            toks = jnp.asarray(
                np.stack([st.req.prompt for _, st in group]), jnp.int32)
            batch = {"tokens": toks}
            extras = [st.req.extra for _, st in group]
            if extras[0]:
                for k in extras[0]:
                    batch[k] = jnp.asarray(
                        np.stack([ex[k] for ex in extras]))
            cache = self.cache.fresh(len(group))
            logits, cache = self._prefill(self.params, batch, cache)
            self.cache.write_slots(cache, [slot for slot, _ in group])
            keys = jnp.stack([st.req.key for _, st in group])
            temps = jnp.asarray(
                [st.req.temperature for _, st in group], jnp.float32)
            steps = jnp.zeros(len(group), jnp.int32)
            first = np.asarray(sample_step(logits, keys, steps, temps))
            for (slot, _), tok in zip(group, first):
                self.scheduler.record_prefill(slot, tok)

    def _decode_once(self) -> None:
        toks, idxs, steps, temps, keys = self.scheduler.decode_batch(
            self._dummy_key)
        logits, tree = self._decode(
            self.params, jnp.asarray(toks)[:, None], self.cache.tree,
            jnp.asarray(idxs))
        self.cache.tree = tree
        nxt = sample_step(logits, jnp.stack(keys), jnp.asarray(steps),
                          jnp.asarray(temps))
        self.scheduler.record_decode(np.asarray(nxt))


# ---------------------------------------------------------------------------
# Legacy static-batch engine (parity oracle)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DecodeEngine:
    """Static-batch decode: prefill + lockstep decode over a ring KV cache,
    fresh cache per ``generate`` call. Superseded by
    :class:`ContinuousBatchingEngine` on the serving path; retained as the
    reference implementation the parity tests pin the new engine against."""

    cfg: ArchConfig
    params: Any
    max_len: int = 256
    batch: int = 1
    packed: bool = False
    quant_cfg: Optional[QuantConfig] = None
    cache_dtype: Any = jnp.float32

    def __post_init__(self):
        self.cfg, self.params, self.pack_stats = _maybe_pack(
            self.cfg, self.params, self.packed, self.quant_cfg)
        self.model = Model(self.cfg)
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode_step, donate_argnums=(2,))

    def new_cache(self):
        tree = self.model.build_cache(self.batch, self.max_len,
                                      self.cache_dtype)
        return pp.init_params(tree, jax.random.key(0))

    def generate(self, prompt: np.ndarray, n_tokens: int,
                 extra: Optional[Dict[str, Any]] = None,
                 temperature: float = 0.0, seed: int = 0) -> np.ndarray:
        """prompt: (B, S0) int32. Returns (B, S0 + n_tokens)."""
        b, s0 = prompt.shape
        assert b == self.batch and s0 + n_tokens <= self.max_len
        cache = self.new_cache()
        batch = {"tokens": jnp.asarray(prompt, jnp.int32)}
        if extra:
            batch.update({k: jnp.asarray(v) for k, v in extra.items()})
        logits, cache = self._prefill(self.params, batch, cache)
        rng = jax.random.key(seed)
        keys = _row_keys(rng, b)
        temps = jnp.full((b,), temperature, jnp.float32)
        out = [jnp.asarray(prompt, jnp.int32)]
        tok = self._sample(logits, keys, temps, 0)
        for i in range(n_tokens):
            out.append(tok)
            if i == n_tokens - 1:
                break
            logits, cache = self._decode(self.params, tok, cache,
                                         jnp.int32(s0 + i))
            tok = self._sample(logits, keys, temps, i + 1)
        return np.asarray(jnp.concatenate(out, axis=1))

    @staticmethod
    def _sample(logits, keys, temps, i):
        steps = jnp.full((logits.shape[0],), i, jnp.int32)
        return sample_step(logits, keys, steps, temps)[:, None]
