"""Batched decode engine: prefill + greedy/temperature decode over a ring KV
cache, with optional SWIS-packed weights (the paper's compressed serving).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.swis import QuantConfig
from repro.models import params as pp
from repro.models.model import Model
from repro.serve.quantized import pack_tree


@dataclasses.dataclass
class DecodeEngine:
    cfg: ArchConfig
    params: Any
    max_len: int = 256
    batch: int = 1
    packed: bool = False
    quant_cfg: Optional[QuantConfig] = None
    cache_dtype: Any = jnp.float32

    def __post_init__(self):
        self.model = Model(self.cfg)
        self.pack_stats = None
        if self.packed:
            qcfg = self.quant_cfg or self.cfg.quant.cfg
            self.params, self.pack_stats = pack_tree(self.params, qcfg)
            # record the pack method so dense()/moe dispatch the right
            # (consecutive vs sparse) unpack semantics
            from repro.configs.base import QuantPolicy

            self.cfg = self.cfg.replace(
                quant=QuantPolicy(cfg=qcfg, mode="off"))
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode_step, donate_argnums=(2,))

    def new_cache(self):
        tree = self.model.build_cache(self.batch, self.max_len,
                                      self.cache_dtype)
        return pp.init_params(tree, jax.random.key(0))

    def generate(self, prompt: np.ndarray, n_tokens: int,
                 extra: Optional[Dict[str, Any]] = None,
                 temperature: float = 0.0, seed: int = 0) -> np.ndarray:
        """prompt: (B, S0) int32. Returns (B, S0 + n_tokens)."""
        b, s0 = prompt.shape
        assert b == self.batch and s0 + n_tokens <= self.max_len
        cache = self.new_cache()
        batch = {"tokens": jnp.asarray(prompt, jnp.int32)}
        if extra:
            batch.update({k: jnp.asarray(v) for k, v in extra.items()})
        logits, cache = self._prefill(self.params, batch, cache)
        rng = jax.random.key(seed)
        out = [jnp.asarray(prompt, jnp.int32)]
        tok = self._sample(logits, rng, temperature, 0)
        for i in range(n_tokens):
            out.append(tok)
            if i == n_tokens - 1:
                break
            logits, cache = self._decode(self.params, tok, cache,
                                         jnp.int32(s0 + i))
            tok = self._sample(logits, rng, temperature, i + 1)
        return np.asarray(jnp.concatenate(out, axis=1))

    @staticmethod
    def _sample(logits, rng, temperature, i):
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        k = jax.random.fold_in(rng, i)
        return jax.random.categorical(
            k, logits / temperature, axis=-1)[:, None].astype(jnp.int32)
