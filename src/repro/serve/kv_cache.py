"""Slot-based paged KV cache for continuous batching.

One batched cache tree holds ``n_slots`` independent request slots. The
batch axis of every leaf is the slot axis (axis 1 under the scanned
``blocks`` subtree — axis 0 there is the layer-stack — and axis 0 under the
unrolled ``tail``). Each slot carries its own position plane
(``pos`` of shape (n_slots, cache_len), built with ``per_slot=True``), so a
new request can prefill into a free slot while the other slots keep
decoding at different depths — the attention mask only ever admits entries
whose ``pos`` row is valid (>= 0), which is what isolates slots from each
other and from stale entries of evicted requests.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import params as pp

# batch (slot) axis per top-level cache subtree: the scanned "blocks" leaves
# carry a leading layer-stack axis, the unrolled "tail" leaves do not.
_SLOT_AXIS = {"blocks": 1, "tail": 0}


class SlotKVCache:
    """Batched per-slot cache tree with scatter/gather on the slot axis."""

    def __init__(self, model, n_slots: int, max_len: int,
                 dtype: Any = jnp.float32):
        self.model = model
        self.n_slots = n_slots
        self.max_len = max_len
        self.dtype = dtype
        self._fresh: dict = {}  # batch -> constant zero-init tree
        # live tree must not alias the memoized constant: the engine's
        # decode jit donates its buffers
        self.tree = jax.tree.map(jnp.copy, self.fresh(n_slots))

    def fresh(self, batch: int):
        """A zero-initialized ``batch``-slot cache (pos planes all -1).
        Memoized per batch size — the content is constant, jax arrays are
        immutable, and prefill does not donate it, so admissions on the
        serving hot path skip the rebuild + device fill."""
        if batch not in self._fresh:
            tree = self.model.build_cache(batch, self.max_len, self.dtype,
                                          per_slot=True)
            self._fresh[batch] = pp.init_params(tree, jax.random.key(0))
        return self._fresh[batch]

    def write_slots(self, slot_tree, slots) -> None:
        """Scatter a ``len(slots)``-slot tree into rows ``slots`` of the
        live cache (used after prefilling admitted requests)."""
        slots = jnp.asarray(np.asarray(slots, np.int32))
        out = {}
        for key, sub in self.tree.items():
            axis = _SLOT_AXIS[key]
            out[key] = jax.tree.map(
                lambda a, b, ax=axis: (a.at[slots].set(b) if ax == 0
                                       else a.at[:, slots].set(b)),
                sub, slot_tree[key])
        self.tree = out
