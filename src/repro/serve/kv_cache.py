"""Slot-based KV cache for continuous batching: block arena + block tables.

Two layouts behind one class:

* **Block mode** (the serving default, and what the prefix cache needs):
  the KV arena is ``n_blocks`` physical blocks of ``block_size`` token
  positions — every cache leaf's batch axis is the *physical block* axis
  (``k``: (n_blocks, block_size, hkv, dh), ``pos``: (n_blocks,
  block_size)). Each slot owns a row of ``block_tables`` mapping its
  logical block ``i`` (token positions ``[i*bs, (i+1)*bs)``) to a physical
  block, so the decode path gathers its K/V *through the table* and two
  slots whose tables point at the same physical block share that KV with
  zero copies. Block 0 is the trash block: free slots' table rows point at
  it so their dummy decode writes land somewhere harmless.

* **Legacy contiguous mode** (``block_size=None``): one batched cache tree
  whose batch axis is the slot axis, as in the original engine. Retained
  for families whose caches are not uniform attention ring buffers
  (recurrent state, sliding-window) where block indirection does not apply.

Either way each slot carries its own position plane and the attention mask
only admits entries whose ``pos`` is valid (>= 0) — that masking contract
is unchanged and is what isolates slots from each other, from stale
entries, and from unwritten block tails.

Speculative commit/rollback contract (``spec_decode``): speculative draft
and verify launches write K/V for proposed tokens into the slot's OWNED
blocks at positions ``[idx, idx + k]`` before knowing which proposals
survive. No explicit rollback is needed, by three standing invariants:
(1) rejected positions sit strictly beyond every later query position
until the next feed window rewrites them, so the causal mask (kv pos <=
q pos) keeps them unread; (2) the engine's per-row draft budget
(``min(spec_k, remaining - 1)``) keeps every speculative write inside the
blocks the slot already owns — never a shared prefix block, never past
``eff_len``; (3) trie commits happen only at release, covering full
blocks of the *fed* token sequence, by which point every committed
position has been rewritten at full precision by the verify launch that
accepted it. Rows sitting out a launch carry ``q_lens = 0`` and route to
the trash block like any other masked write.
"""
from __future__ import annotations

import collections
import functools
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import params as pp

# batch axis per top-level cache subtree: the scanned "blocks" leaves carry
# a leading layer-stack axis, the unrolled "tail" leaves do not.
_SLOT_AXIS = {"blocks": 1, "tail": 0}

_FRESH_MEMO_CAP = 8  # bounded zero-tree memo (keyed to bucketed sizes)


def _is_attn_cache(d) -> bool:
    return isinstance(d, dict) and set(d) == {"k", "v", "pos"}


class SlotKVCache:
    """Batched per-slot cache: block-table indirection or contiguous rows."""

    def __init__(self, model, n_slots: int, max_len: int,
                 dtype: Any = jnp.float32, block_size: Optional[int] = None,
                 n_blocks: Optional[int] = None):
        self.model = model
        self.n_slots = n_slots
        self.max_len = max_len
        self.dtype = dtype
        self.block_size = block_size
        # bounded memo of constant zero-init trees, LRU on (batch, length)
        self._fresh: collections.OrderedDict = collections.OrderedDict()
        if block_size is None:
            self.tree = jax.tree.map(jnp.copy, self.fresh(n_slots))
            return
        self.blocks_per_slot = -(-max_len // block_size)
        self.eff_len = self.blocks_per_slot * block_size
        # +1 for the reserved trash block; default arena leaves room for
        # two slots' worth of cached-but-unreferenced prefix blocks
        self.n_blocks = n_blocks or (
            n_slots * self.blocks_per_slot + 2 * self.blocks_per_slot + 1)
        arena = self.model.build_cache(self.n_blocks, block_size, self.dtype,
                                       per_slot=True)
        # live arena must not alias a memoized constant: decode donates it
        self.tree = jax.tree.map(jnp.copy, pp.init_params(
            arena, jax.random.key(0)))
        self.block_tables = np.zeros((n_slots, self.blocks_per_slot),
                                     np.int32)
        self._tables_dev = None  # refreshed lazily after table mutations

    # -- shared helpers --------------------------------------------------

    @staticmethod
    def supports_blocks(model, max_len: int) -> bool:
        """Block mode applies iff every cache leaf is a standard attention
        ring cache spanning the full ``max_len`` (no recurrent state, no
        window-truncated local attention)."""
        spec = model.build_cache(1, max_len, per_slot=True)
        for key, sub in spec.items():
            if key not in _SLOT_AXIS:
                return False
            for blk in sub.values():
                if not _is_attn_cache(blk):
                    return False
                if blk["k"].shape[-3] != max_len:
                    return False
        return True

    def fresh(self, batch: int, length: Optional[int] = None):
        """A zero-initialized ``batch``-row cache tree of ``length`` token
        positions (pos planes all -1). Memoized — the content is constant,
        jax arrays are immutable, and prefill does not donate it — with a
        bounded LRU so distinct (bucketed) admission sizes cannot grow the
        memo without bound."""
        length = length or (self.eff_len if self.block_size else self.max_len)
        key = (batch, length)
        if key not in self._fresh:
            tree = self.model.build_cache(batch, length, self.dtype,
                                          per_slot=True)
            self._fresh[key] = pp.init_params(tree, jax.random.key(0))
            while len(self._fresh) > _FRESH_MEMO_CAP:
                self._fresh.popitem(last=False)
        self._fresh.move_to_end(key)
        return self._fresh[key]

    # -- legacy contiguous mode ------------------------------------------

    def write_slots(self, slot_tree, slots) -> None:
        """Scatter a ``len(slots)``-row tree into rows ``slots`` of the
        live cache (legacy mode, after prefilling admitted requests)."""
        assert self.block_size is None
        slots = jnp.asarray(np.asarray(slots, np.int32))
        out = {}
        for key, sub in self.tree.items():
            axis = _SLOT_AXIS[key]
            out[key] = jax.tree.map(
                lambda a, b, ax=axis: (a.at[slots].set(b) if ax == 0
                                       else a.at[:, slots].set(b)),
                sub, slot_tree[key])
        self.tree = out

    @staticmethod
    def mask_pos_tail(slot_tree, valid_lens: Sequence[int]):
        """Invalidate (-1) each row's pos entries at index >= valid_lens[r]
        — bucket-padded prefill writes positions for pad tokens too, and
        those must never enter a future attention mask."""
        valid = jnp.asarray(np.asarray(valid_lens, np.int32))

        def fix(sub, axis):
            def leaf(path, a):
                if str(path[-1].key) != "pos":
                    return a
                idx = jnp.arange(a.shape[-1], dtype=jnp.int32)
                keep = idx[None, :] < valid[:, None]  # (g, L)
                if axis == 1:  # leading layer-stack axis
                    keep = keep[None]
                return jnp.where(keep, a, -1)
            return jax.tree_util.tree_map_with_path(leaf, sub)

        return {key: fix(sub, _SLOT_AXIS[key])
                for key, sub in slot_tree.items()}

    # -- block mode -------------------------------------------------------

    def tables_device(self):
        if self._tables_dev is None:
            self._tables_dev = jnp.asarray(self.block_tables)
        return self._tables_dev

    def set_table(self, slot: int, blocks: Sequence[int]) -> None:
        """Point ``slot``'s logical blocks at physical ``blocks``; the rest
        of the row falls back to the trash block 0."""
        row = np.zeros(self.blocks_per_slot, np.int32)
        row[:len(blocks)] = blocks
        # the trash-block convention decode masking relies on: physical
        # block 0 is reserved (BlockPool pins it off the free list) and
        # must never back live storage — a table entry of 0 *means*
        # "invalid", so a live block numbered 0 would be silently masked
        assert not np.any(row[:len(blocks)] == 0), \
            f"live table entry maps to reserved trash block 0: {blocks}"
        self.block_tables[slot] = row
        self._tables_dev = None

    def clear_table(self, slot: int) -> None:
        self.block_tables[slot] = 0
        self._tables_dev = None

    def group_tables(self, block_lists: Sequence[Sequence[int]]) -> np.ndarray:
        """Per-row block tables for rows that are NOT live slots — the
        fused mixed step's chunk rows route their in-launch commits and
        reads through these while the slot's own table stays parked on the
        trash block until the final chunk lands. Rows are padded with the
        trash block 0 (same "0 means invalid" contract as ``set_table``)."""
        tables = np.zeros((len(block_lists), self.blocks_per_slot), np.int32)
        for i, blocks in enumerate(block_lists):
            assert not any(b == 0 for b in blocks), \
                f"group table maps to reserved trash block 0: {blocks}"
            tables[i, :len(blocks)] = blocks
        return tables

    def invalidate_blocks(self, block_ids: Sequence[int]) -> None:
        """Set the pos plane of physical ``block_ids`` to -1 (K/V left as
        garbage — masked by pos). Freshly allocated blocks may hold stale
        positions from a previous owner; chunked prefill commits a slot's
        blocks incrementally, so the blocks it has not reached yet must be
        scrubbed up front rather than by one whole-table scatter. Jit'd
        and donating, like ``scatter_row``; the id vector is padded to a
        pow2 bucket with the trash block 0 (whose pos is garbage by
        definition and masked at decode), so the jit cache holds one
        entry per bucket, not one per distinct block count."""
        if not len(block_ids):
            return
        ids = np.asarray(block_ids, np.int32)
        bucket = 1 << max(len(ids) - 1, 0).bit_length()
        ids = np.pad(ids, (0, bucket - len(ids)))
        self.tree = self._invalidate(self.tree, jnp.asarray(ids))

    @functools.cached_property
    def _invalidate(self):
        def fix(tree, ids):
            def leaf(path, a, axis):
                if str(path[-1].key) != "pos":
                    return a
                if axis == 0:
                    return a.at[ids].set(-1)
                return a.at[:, ids].set(-1)
            return {key: jax.tree_util.tree_map_with_path(
                        lambda p, a, ax=_SLOT_AXIS[key]: leaf(p, a, ax), sub)
                    for key, sub in tree.items()}
        return jax.jit(fix, donate_argnums=(0,))

    def prefix_tree(self, block_ids: Sequence[Sequence[int]],
                    prefix_len: int, length: Optional[int] = None):
        """A ``g``-row contiguous cache of ``length`` positions (default
        ``eff_len``) whose rows [0, prefix_len) are gathered from the arena
        blocks ``block_ids`` ((g, prefix_len//bs) physical ids) — the
        working tree for prefilling past any committed position (a cached
        prefix, or the chunks committed so far). ``length`` lets chunked
        prefill attend over just committed + chunk instead of the full
        slot capacity. prefix_len == 0 returns the memoized fresh tree
        directly (safe: prefill does not donate its cache). The gather
        runs as ONE jit'd ``take``-based call over the whole tree (keyed
        on (g, prefix blocks, length) — all bucketed), not an eager
        per-leaf/per-block loop: one dispatch per admission."""
        g = len(block_ids)
        base = self.fresh(g, length)
        if prefix_len == 0:
            return base
        ids = np.asarray(block_ids, np.int32)
        assert ids.size * self.block_size == g * prefix_len, \
            (ids.shape, prefix_len)
        assert not np.any(ids == 0), \
            f"cached prefix references reserved trash block 0: {block_ids}"
        return self._gather_prefix(base, self.tree, jnp.asarray(ids))

    @functools.cached_property
    def _gather_prefix(self):
        def gather(base, arena, ids2d):
            g, nbp = ids2d.shape
            plen = nbp * self.block_size
            ids = ids2d.reshape(-1)

            def graft(dst, src, axis):
                if axis == 0:  # (n_blocks, bs, ...) -> rows (g, pref, ...)
                    pref = jnp.take(src, ids, axis=0).reshape(
                        (g, plen) + src.shape[2:])
                    return dst.at[:, :plen].set(pref)
                # (layers, n_blocks, bs, ...) -> (layers, g, pref, ...)
                pref = jnp.take(src, ids, axis=1).reshape(
                    (src.shape[0], g, plen) + src.shape[3:])
                return dst.at[:, :, :plen].set(pref)

            return {key: jax.tree.map(
                        lambda d, s, ax=_SLOT_AXIS[key]: graft(d, s, ax),
                        base[key], arena[key])
                    for key in arena}
        return jax.jit(gather)

    def scatter_row(self, slot_tree, row: int, block_ids: Sequence[int],
                    first_block: int, n_valid: int) -> None:
        """Commit one prefilled row's region into its owned arena blocks:
        logical blocks [first_block, first_block + len(block_ids)) of
        ``slot_tree`` row ``row`` overwrite physical ``block_ids`` —
        chunked prefill appends each chunk at its offset this way. Pos
        entries beyond ``n_valid`` tokens past the region start (bucket
        padding, unwritten tail) are invalidated so they never match the
        attention mask. Runs as a jit'd donating update (keyed on the
        block count and the working-tree shape), so committing a chunk
        costs one in-place arena write, not an eager whole-arena copy."""
        if not len(block_ids):
            return
        ids = np.asarray(block_ids, np.int32)
        assert not np.any(ids == 0), \
            f"commit targets reserved trash block 0: {block_ids}"
        self.tree = self._scatter(
            self.tree, slot_tree, jnp.asarray(ids),
            jnp.int32(row), jnp.int32(first_block * self.block_size),
            jnp.int32(n_valid))

    @functools.cached_property
    def _scatter(self):
        return jax.jit(functools.partial(_scatter_arena, bs=self.block_size),
                       donate_argnums=(0,))


def _scatter_arena(arena_tree, slot_tree, ids, row, lo, n_valid, *, bs):
    """Jit body of :meth:`SlotKVCache.scatter_row`: write ``len(ids)``
    blocks of ``slot_tree`` row ``row`` starting at token offset ``lo``
    into physical arena blocks ``ids`` (pos masked past ``n_valid``)."""
    nb = ids.shape[0]
    keep = jnp.arange(nb * bs, dtype=jnp.int32) < n_valid

    def put(arena, src, axis, is_pos):
        if axis == 0:
            reg = jax.lax.dynamic_slice_in_dim(src[row], lo, nb * bs, axis=0)
            if is_pos:
                reg = jnp.where(keep, reg, -1)
            return arena.at[ids].set(reg.reshape((nb, bs) + reg.shape[1:]))
        reg = jax.lax.dynamic_slice_in_dim(src[:, row], lo, nb * bs, axis=1)
        if is_pos:
            reg = jnp.where(keep[None], reg, -1)
        return arena.at[:, ids].set(
            reg.reshape((reg.shape[0], nb, bs) + reg.shape[2:]))

    out = {}
    for key, sub in arena_tree.items():
        axis = _SLOT_AXIS[key]
        out[key] = jax.tree_util.tree_map_with_path(
            lambda path, a, b, ax=axis: put(
                a, b, ax, str(path[-1].key) == "pos"),
            sub, slot_tree[key])
    return out
