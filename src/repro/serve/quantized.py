"""SWIS-packed parameters for serving.

``pack_tree`` walks a parameter tree and replaces every eligible GEMM weight
(2-D ``{'w': (K, C)}`` leaves and 3-D per-expert stacks) with its packed SWIS
representation {sign_plane, mask_planes, shifts, scale}. The model's
``dense`` path detects packed leaves and dequantizes in-kernel (Pallas on
TPU, jnp reference on CPU/dry-run) — HBM weight traffic is the *packed*
bytes, which is where the paper's compression lands on TPU.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing
from repro.core.swis import QuantConfig, quantize

PACKED_KEYS = ("sign_plane", "mask_planes", "shifts", "scale")


def is_packed(leaf) -> bool:
    return isinstance(leaf, dict) and "mask_planes" in leaf


def _eligible(path_keys, arr) -> bool:
    # any rank >= 2: trailing (K, C) is the GEMM matrix, leading dims are
    # stacked layers and/or experts
    if len(arr.shape) < 2:
        return False
    k = arr.shape[-2]
    if k % 32 or k < 64:
        return False
    name = str(path_keys[-1])
    if name not in ("w", "wi", "wo", "wg", "shared_wi", "shared_wo",
                    "shared_wg"):
        return False
    joined = "/".join(str(p) for p in path_keys)
    if "embed" in joined or "router" in joined or "frontend" in joined:
        return False
    return True


def _pack_matrix(w: jnp.ndarray, qcfg: QuantConfig) -> Dict[str, jnp.ndarray]:
    qw = quantize(jnp.asarray(w, jnp.float32), qcfg)
    pw = packing.pack(qw)
    return {
        "sign_plane": pw.sign_plane,
        "mask_planes": pw.mask_planes,
        "shifts": pw.shifts,
        "scale": jnp.asarray(pw.scale, jnp.float32).reshape(1, -1)
        if jnp.ndim(pw.scale) else jnp.full((1, w.shape[-1]), pw.scale),
    }


def pack_tree(params, qcfg: QuantConfig):
    """Returns (packed_tree, stats). Non-eligible leaves pass through."""
    n_packed = 0
    dense_bits = 0
    packed_bits = 0

    def walk(path, node):
        nonlocal n_packed, dense_bits, packed_bits
        if isinstance(node, dict):
            return {k: walk(path + (k,), v) for k, v in node.items()}
        arr = node
        if not _eligible(path, arr):
            return arr
        if arr.ndim > 2:
            lead = arr.shape[:-2]
            flat = arr.reshape(-1, *arr.shape[-2:])
            packed = [_pack_matrix(flat[i], qcfg)
                      for i in range(flat.shape[0])]
            out = {k: jnp.stack([p[k] for p in packed]).reshape(
                lead + packed[0][k].shape) for k in PACKED_KEYS}
        else:
            out = _pack_matrix(arr, qcfg)
        n_packed += 1
        k, c = arr.shape[-2], arr.shape[-1]
        e = int(np.prod(arr.shape[:-2])) if arr.ndim > 2 else 1
        dense_bits += e * k * c * 8
        n = int(out["mask_planes"].shape[-3])
        groups = k // qcfg.group_size * c
        shift_bits = 3 if qcfg.method == "swis_c" else 3 * n
        packed_bits += e * (k * c * (1 + n) + groups * shift_bits)
        return out

    tree = walk((), params)
    stats = {
        "n_packed": n_packed,
        "dense_bits": dense_bits,
        "packed_bits": packed_bits,
        "compression": dense_bits / max(packed_bits, 1),
    }
    return tree, stats


def pack_placeholders(tree, qcfg: QuantConfig):
    """Placeholder-tree version of :func:`pack_tree` (dry-run: shapes +
    logical axes only, no data). Eligible P leaves become dicts of P leaves
    with the packed shapes; sharding rules apply to them like any other."""
    from repro.models.params import P, is_placeholder

    n_eff = int(np.ceil(qcfg.n_shifts))
    m = qcfg.group_size

    def walk(path, node):
        if isinstance(node, dict):
            return {k: walk(path + (k,), v) for k, v in node.items()}
        p = node
        if not is_placeholder(p) or not _eligible(path, p):
            return p
        lead = p.shape[:-2]
        lead_axes = p.axes[:-2]
        k, c = p.shape[-2], p.shape[-1]
        ak, ac = p.axes[-2], p.axes[-1]
        if k % m:
            return p
        return {
            "sign_plane": P(lead + (k // 32, c), lead_axes + (ak, ac),
                            init="zeros", dtype=jnp.uint32),
            "mask_planes": P(lead + (n_eff, k // 32, c),
                             lead_axes + (None, ak, ac),
                             init="zeros", dtype=jnp.uint32),
            # nibble-packed shift values (SWIS-C: one offset byte/group)
            "shifts": P(lead + (k // m, c,
                                1 if qcfg.method == "swis_c"
                                else (n_eff + 1) // 2),
                        lead_axes + (ak, ac, None),
                        init="zeros", dtype=jnp.uint8),
            "scale": P(lead + (1, c), lead_axes + (None, ac),
                       init="ones", dtype=jnp.float32),
        }

    return walk((), tree)


def total_slices(tree) -> int:
    """Number of SWIS bit-slices (mask planes) in a packed tree, from the
    first packed leaf (``pack_tree`` packs every leaf with one config, so
    the count is uniform). 0 when the tree holds no packed leaves — the
    engine uses this to validate ``draft_slices`` for speculative decode.
    """
    found = 0

    def walk(node):
        nonlocal found
        if found:
            return
        if is_packed(node):
            found = int(node["mask_planes"].shape[-3])
            return
        if isinstance(node, dict):
            for v in node.values():
                walk(v)

    walk(tree)
    return found


def packed_stats(tree) -> Dict[str, int]:
    n = 0

    def count(node):
        nonlocal n
        if is_packed(node):
            n += 1
            return
        if isinstance(node, dict):
            for v in node.values():
                count(v)

    count(tree)
    return {"n_packed_leaves": n}


def dequant_leaf(leaf: Dict[str, jnp.ndarray], dtype=jnp.float32,
                 consecutive: bool = False) -> jnp.ndarray:
    """Dense weights from a packed leaf (2-D or stacked 3-D)."""
    from repro.kernels.ref import dequant_ref

    mask = leaf["mask_planes"]
    if mask.ndim == 4:  # (E, N, K/32, C)
        k = leaf["sign_plane"].shape[-2] * 32
        group = k // leaf["shifts"].shape[-3]
        return jax.vmap(
            lambda s, m, sh, sc: dequant_ref(s, m, sh, sc, group=group,
                                             dtype=dtype,
                                             consecutive=consecutive)
        )(leaf["sign_plane"], mask, leaf["shifts"], leaf["scale"])
    k = leaf["sign_plane"].shape[0] * 32
    group = k // leaf["shifts"].shape[0]
    return dequant_ref(leaf["sign_plane"], mask, leaf["shifts"],
                       leaf["scale"], group=group, dtype=dtype,
                       consecutive=consecutive)
