"""Typed construction / submission surface for the serve engine.

:class:`EngineConfig` gathers what used to be 15 loose
``ContinuousBatchingEngine.__init__`` kwargs into one frozen, validated
dataclass (grouped: capacity, cache, prefill, kernel, observability), and
:class:`SamplingParams` replaces the positional ``n_tokens / temperature /
key / seed`` threading through ``submit()``. Validation that used to
surface deep inside the engine (or worse, inside a jitted step — an
unknown ``paged_impl`` used to sail through construction and explode on
the first decode) happens eagerly in ``__post_init__`` with actionable
messages. The legacy kwarg surfaces still work behind
``DeprecationWarning`` shims in the engine; see docs/serving.md for the
migration table.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax.numpy as jnp

from repro.core.swis import QuantConfig
from repro.kernels.paged_attention import VALID_PAGED_IMPLS


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """All :class:`~repro.serve.engine.ContinuousBatchingEngine` knobs.

    Capacity:
      max_len — per-slot token capacity (prompt + generated);
      n_slots — concurrent decode slots.
    Cache:
      block_size — KV arena block granularity (block mode);
      n_cache_blocks — extra arena blocks beyond the slots' own capacity
        (None: two slots' worth, for cached-but-unreferenced prefixes);
      cache_dtype — KV storage dtype;
      prefix_cache — block arena + radix prefix cache (uniform attention
        families only; the engine falls back to contiguous rows when the
        family's cache is not block-compatible).
    Prefill:
      prefill_chunk — max prompt tokens prefetched per step (None:
        whole-prompt prefill); rounded up to a block multiple;
      prefill_backlog — max in-flight chunk groups before admission
        pauses;
      bucket_prompts — pad prefill lengths to pow2 buckets (bounded jit
        cache);
      fused_step — fold each step's prefill chunk and decode batch into
        ONE ``mixed_step`` dispatch (requires prefill_chunk; the separate
        two-launch path remains the token-exact parity reference when
        off).
    Kernel:
      packed — serve from SWIS bit-plane packed weights;
      quant_cfg — packing config (None: the arch's default policy);
      use_paged_kernel — paged-attention decode over the arena (no
        gathered K/V);
      paged_impl — kernel backend override: one of "pallas",
        "pallas_interpret", "xla" (None: auto — "pallas" on TPU, "xla"
        elsewhere).
    Speculative decode:
      spec_decode — self-speculative multi-token decode: a draft pass
        proposes up to spec_k tokens per step, one full-precision verify
        launch scores them all, the longest matching prefix (plus the
        verify's bonus token) is accepted — token-exact vs. plain decode
        for every accept pattern (block mode only);
      spec_k — max draft tokens proposed per step (>= 1);
      draft_slices — run draft passes with SWIS weights truncated to this
        many most-significant bit-slices (requires packed=True; None:
        the draft runs at full precision, accept rate 1.0).
    Observability:
      enable_metrics — phase timers / counters / lifecycle tracer;
      trace_capacity — trace ring size (events).
    """

    max_len: int = 256
    n_slots: int = 4
    # cache
    block_size: int = 8
    n_cache_blocks: Optional[int] = None
    cache_dtype: Any = jnp.float32
    prefix_cache: bool = True
    # prefill
    prefill_chunk: Optional[int] = None
    prefill_backlog: int = 2
    bucket_prompts: bool = True
    fused_step: bool = False
    # kernel
    packed: bool = False
    quant_cfg: Optional[QuantConfig] = None
    use_paged_kernel: bool = False
    paged_impl: Optional[str] = None
    # speculative decode
    spec_decode: bool = False
    spec_k: int = 3
    draft_slices: Optional[int] = None
    # observability
    enable_metrics: bool = True
    trace_capacity: int = 65536

    def __post_init__(self):
        for name, floor in (("max_len", 1), ("n_slots", 1),
                            ("block_size", 1), ("prefill_backlog", 1),
                            ("trace_capacity", 1)):
            if getattr(self, name) < floor:
                raise ValueError(f"{name} must be >= {floor}, "
                                 f"got {getattr(self, name)}")
        if self.prefill_chunk is not None and self.prefill_chunk < 1:
            raise ValueError(
                f"prefill_chunk must be >= 1 (or None for whole-prompt "
                f"prefill), got {self.prefill_chunk}")
        if self.n_cache_blocks is not None and self.n_cache_blocks < 0:
            raise ValueError(
                f"n_cache_blocks must be >= 0, got {self.n_cache_blocks}")
        # block-mode requirements, checked here so misconfiguration fails
        # at construction, not steps deep into serving (the engine still
        # rejects block-incompatible model families at build time)
        if self.prefill_chunk is not None and not self.prefix_cache:
            raise ValueError(
                "prefill_chunk requires the block-mode prefix cache "
                "(prefix_cache=True)")
        if self.use_paged_kernel and not self.prefix_cache:
            raise ValueError(
                "use_paged_kernel requires the block-mode prefix cache "
                "(prefix_cache=True)")
        if self.fused_step and self.prefill_chunk is None:
            raise ValueError(
                "fused_step fuses the per-step prefill chunk into the "
                "decode dispatch and requires prefill_chunk to be set")
        # an unknown impl used to sail through __init__ and only fail
        # inside the first jitted decode step — reject it eagerly
        if (self.paged_impl is not None
                and self.paged_impl not in VALID_PAGED_IMPLS):
            raise ValueError(
                f"unknown paged_impl {self.paged_impl!r}; valid impls: "
                f"{', '.join(VALID_PAGED_IMPLS)} (or None for backend "
                f"auto-pick)")
        if self.paged_impl is not None and not self.use_paged_kernel:
            raise ValueError(
                "paged_impl is set but use_paged_kernel=False — enable "
                "the paged kernel or drop the impl override")
        if self.spec_decode and not self.prefix_cache:
            raise ValueError(
                "spec_decode requires the block-mode prefix cache "
                "(prefix_cache=True): draft and verify launches route "
                "per-row token counts through the block tables")
        if self.spec_decode and self.spec_k < 1:
            raise ValueError(
                f"spec_k must be >= 1 when spec_decode is on, got "
                f"{self.spec_k}")
        if self.draft_slices is not None:
            if not self.spec_decode:
                raise ValueError(
                    "draft_slices is set but spec_decode=False — enable "
                    "speculative decode or drop the truncation knob")
            if not self.packed:
                raise ValueError(
                    "draft_slices truncates the SWIS bit-plane kernel "
                    "path and requires packed=True (unpacked weights "
                    "have no slices to truncate)")
            if self.draft_slices < 1:
                raise ValueError(
                    f"draft_slices must be >= 1, got {self.draft_slices}")


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling contract for ``submit(prompt, params)``.

    max_tokens — tokens to generate (0 allowed: prefill-only request);
    temperature — 0 greedy, > 0 seeded categorical;
    seed / key — reproducible sampling stream (mutually exclusive; when
      neither is given the engine derives a distinct auto-key per
      request, so independent clients never draw identical streams).
    """

    max_tokens: int
    temperature: float = 0.0
    seed: Optional[int] = None
    key: Any = None

    def __post_init__(self):
        if self.max_tokens < 0:
            raise ValueError(
                f"max_tokens must be >= 0, got {self.max_tokens}")
        if self.temperature < 0:
            raise ValueError(
                f"temperature must be >= 0, got {self.temperature}")
        if self.seed is not None and self.key is not None:
            raise ValueError("seed and key are mutually exclusive — pass "
                             "one reproducibility handle, not both")
