"""Radix-tree prefix cache: block-granular KV sharing across requests.

SWIS deduplicates *weights* (shared shift values across groups); at serving
scale the same economics apply to *activations*. The KV arena is carved into
fixed-size blocks of ``block_size`` token positions. A completed request
commits the full blocks of its token sequence into a radix trie keyed on the
block's token contents; a later request whose prompt shares a block-aligned
prefix re-references those physical blocks (refcount++) instead of
recomputing them, and prefills only the uncached suffix.

Two pieces, both pure host-side bookkeeping (the K/V payload lives in the
:class:`~repro.serve.kv_cache.SlotKVCache` device arena):

* :class:`BlockPool` — free-list + per-block slot refcounts over the arena.
  Block 0 is reserved as the garbage sink for free-slot dummy decode writes
  and is never allocated.
* :class:`RadixPrefixCache` — trie of committed blocks. One node per block;
  an edge is the ``block_size``-token chunk it covers. Unreferenced leaf
  nodes are evictable, LRU-first, so the trie doubles as the eviction queue.

Invariants (pinned by ``tests/test_prefix_cache.py``):
  * a matched prefix is always a chain of committed blocks from the root;
  * refcounts never go negative (``decref`` raises);
  * eviction never drops a block that is referenced or has children.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np


class BlockPool:
    """Free-list + slot refcounts over ``n_blocks`` physical KV blocks.

    ``refcount`` counts *slot* references only; trie membership is tracked
    by the :class:`RadixPrefixCache` that owns this pool. A block at
    refcount 0 that is not committed to the trie belongs on the free list.
    """

    def __init__(self, n_blocks: int, block_size: int):
        if n_blocks < 2:
            raise ValueError(f"need >= 2 blocks (one is the trash block), "
                             f"got {n_blocks}")
        self.n_blocks = n_blocks
        self.block_size = block_size
        self.refcount = np.zeros(n_blocks, np.int64)
        # Block 0 is the reserved trash block: free-slot dummy decode
        # writes land there and block-table entry 0 means "invalid" to
        # the paged decode kernel. Pin its refcount so free([0]) raises
        # and it can never re-enter circulation as live storage.
        self.refcount[0] = 1
        # LIFO free list; block 0 reserved as the trash block
        self._free: List[int] = list(range(n_blocks - 1, 0, -1))

    def n_free(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` free blocks, or None (caller evicts and retries)."""
        if n > len(self._free):
            return None
        return [self._free.pop() for _ in range(n)]

    def free(self, ids: Sequence[int]) -> None:
        for b in ids:
            if self.refcount[b] != 0:
                raise RuntimeError(f"freeing referenced block {b} "
                                   f"(rc={self.refcount[b]})")
            self._free.append(int(b))

    def incref(self, ids: Sequence[int]) -> None:
        for b in ids:
            self.refcount[b] += 1

    def occupancy(self) -> Dict[str, float]:
        """Arena occupancy gauges for ``engine.metrics()['block_pool']``
        (the reserved trash block 0 is excluded from the usable count)."""
        usable = self.n_blocks - 1
        free = len(self._free)
        return {"n_blocks": self.n_blocks,
                "usable_blocks": usable,
                "free_blocks": free,
                "used_blocks": usable - free,
                "referenced_blocks": int(
                    np.count_nonzero(self.refcount[1:])),
                "occupancy": (usable - free) / max(usable, 1)}

    def decref(self, ids: Sequence[int]) -> None:
        for b in ids:
            self.refcount[b] -= 1
            if self.refcount[b] < 0:
                raise RuntimeError(f"refcount of block {b} went negative")


class _Node:
    __slots__ = ("chunk", "block", "parent", "children", "tick")

    def __init__(self, chunk: bytes, block: int, parent: Optional["_Node"]):
        self.chunk = chunk
        self.block = block
        self.parent = parent
        self.children: Dict[bytes, "_Node"] = {}
        self.tick = 0


class RadixPrefixCache:
    """Trie of committed KV blocks keyed on token-block contents."""

    def __init__(self, pool: BlockPool):
        self.pool = pool
        self._root = _Node(b"", -1, None)
        self._node_of_block: Dict[int, _Node] = {}
        self._tick = 0
        # counters surfaced via stats(); lookups/hits/hit_blocks are
        # incremented by the caller on *successful* admission only, so a
        # pool-starved request retried across many steps counts once
        self.lookups = 0
        self.hits = 0
        self.hit_blocks = 0
        self.commits = 0
        self.evictions = 0

    # -- key encoding ----------------------------------------------------

    def _chunks(self, tokens: np.ndarray) -> List[bytes]:
        bs = self.pool.block_size
        toks = np.asarray(tokens, np.int32)
        return [toks[i:i + bs].tobytes()
                for i in range(0, (len(toks) // bs) * bs, bs)]

    # -- lookup ----------------------------------------------------------

    def match(self, tokens: np.ndarray,
              max_blocks: Optional[int] = None) -> List[int]:
        """Longest committed block-chain prefix of ``tokens``. Returns the
        physical block ids root-outward and refreshes their LRU recency.
        Does not count stats — call :meth:`count_lookup` once the lookup
        actually leads to an admission."""
        return self._walk(tokens, max_blocks, touch=True)

    def count_lookup(self, matched: List[int]) -> None:
        self.lookups += 1
        if matched:
            self.hits += 1
            self.hit_blocks += len(matched)

    def peek_blocks(self, tokens: np.ndarray,
                    max_blocks: Optional[int] = None) -> int:
        """Match length in blocks without touching recency or counters
        (cache-aware admission scoring must not perturb the LRU)."""
        return len(self._walk(tokens, max_blocks, touch=False))

    def _walk(self, tokens, max_blocks, touch: bool) -> List[int]:
        node = self._root
        ids: List[int] = []
        chunks = self._chunks(tokens)
        if max_blocks is not None:
            chunks = chunks[:max_blocks]
        if touch:
            self._tick += 1
        for chunk in chunks:
            nxt = node.children.get(chunk)
            if nxt is None:
                break
            if touch:
                nxt.tick = self._tick
            ids.append(nxt.block)
            node = nxt
        return ids

    # -- commit ----------------------------------------------------------

    def commit(self, tokens: np.ndarray, block_ids: Sequence[int]) -> None:
        """Commit ``block_ids[i]`` as the cache entry for the i-th full
        token block of ``tokens``. Chunks already present keep their
        existing block (the caller's duplicate stays slot-owned and is
        freed on release); absent chunks adopt the caller's block."""
        chunks = self._chunks(tokens)
        assert len(block_ids) <= len(chunks), (len(block_ids), len(chunks))
        self._tick += 1
        node = self._root
        for chunk, blk in zip(chunks, block_ids):
            nxt = node.children.get(chunk)
            if nxt is None:
                blk = int(blk)
                if blk in self._node_of_block:
                    # physical block already backs a different chain; do
                    # not alias — stop committing this chain here
                    break
                nxt = _Node(chunk, blk, node)
                node.children[chunk] = nxt
                self._node_of_block[blk] = nxt
                self.commits += 1
            nxt.tick = self._tick
            node = nxt

    # -- release / eviction ---------------------------------------------

    def release(self, block_ids: Sequence[int]) -> None:
        """Drop one slot reference per block; blocks that are neither
        referenced nor committed go back to the free list."""
        self.pool.decref(block_ids)
        self.pool.free([b for b in block_ids
                        if self.pool.refcount[b] == 0
                        and b not in self._node_of_block])

    def is_committed(self, block: int) -> bool:
        return block in self._node_of_block

    def n_cached(self) -> int:
        return len(self._node_of_block)

    def evict(self, n: int) -> int:
        """Evict up to ``n`` unreferenced leaf blocks, LRU-first, back to
        the free list. Returns the number evicted. Interior nodes become
        eligible as their children go; referenced blocks never do."""
        evicted = 0
        while evicted < n:
            victim = None
            for node in self._node_of_block.values():
                if node.children or self.pool.refcount[node.block] != 0:
                    continue
                if victim is None or node.tick < victim.tick:
                    victim = node
            if victim is None:
                break
            del victim.parent.children[victim.chunk]
            del self._node_of_block[victim.block]
            self.pool.free([victim.block])
            self.evictions += 1
            evicted += 1
        return evicted

    # -- stats -----------------------------------------------------------

    def stats(self) -> Dict[str, float]:
        return {
            "lookups": self.lookups,
            "hits": self.hits,
            "hit_rate": self.hits / max(self.lookups, 1),
            "hit_blocks": self.hit_blocks,
            "commits": self.commits,
            "evictions": self.evictions,
            "cached_blocks": self.n_cached(),
            "free_blocks": self.pool.n_free(),
        }
