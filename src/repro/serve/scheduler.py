"""Request scheduling for the continuous-batching serve engine.

``RequestScheduler`` owns the admission queue and the per-slot request
state. The engine drives it step-by-step:

  submit()        enqueue a request (any time, including mid-flight)
  admit()         pop queued requests into free slots -> they need prefill
  record_prefill  store a request's first sampled token after prefill
  decode_batch    flatten live slot state into the per-slot decode arrays
  record_decode   append one sampled token to every slot that decoded
  pop_finished    collect requests that hit their token budget (slot freed)

Slots are freed eagerly on completion, so a queued request can be admitted
on the very next step while the remaining slots keep decoding — the
mid-flight interleaving that a static batch engine cannot do.

Request lifecycle: QUEUED (in the deque, no slot) -> PREFILLING (admitted
into a slot, prompt not yet fully in the KV cache — with chunked prefill
this spans several steps) -> DECODING (first token sampled, one token per
decode step). PREFILLING slots are invisible to ``decode_batch`` /
``needs_decode``: their KV is still being written chunk by chunk, so the
other slots keep decoding around them.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S0,) int32
    n_tokens: int
    temperature: float
    key: Any  # jax PRNG key for seeded sampling
    extra: Optional[Dict[str, np.ndarray]] = None  # e.g. vlm patches


# Slot phases. A request starts QUEUED (still in the deque — it has no
# SlotState yet); admission creates its SlotState in PREFILLING; the first
# sampled token moves it to DECODING.
PREFILLING = "prefilling"
DECODING = "decoding"


@dataclasses.dataclass
class SlotState:
    req: Request
    n_gen: int = 0  # tokens sampled so far (incl. the prefill token)
    last_tok: int = 0
    tokens: List[int] = dataclasses.field(default_factory=list)
    phase: str = PREFILLING


@dataclasses.dataclass
class Finished:
    rid: int
    prompt: np.ndarray
    tokens: np.ndarray  # (n_tokens,) generated


class RequestScheduler:
    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self.queue: collections.deque = collections.deque()
        self.slots: List[Optional[SlotState]] = [None] * n_slots
        self._next_rid = 0
        self._finished: List[Finished] = []
        self._decoding: List[int] = []
        # gauges, maintained incrementally on every transition (admit /
        # unadmit / record_prefill / finish) rather than recounted per
        # step — ``gauges()`` exposes them and ``recount()`` recomputes
        # them from SlotStates so tests can pin "no drift", in particular
        # across ``unadmit()`` rollbacks under pool starvation
        self.n_active = 0        # slots holding a request (any phase)
        self.n_prefilling = 0    # slots still landing their prompt
        # lifetime counters (monotonic; engine.metrics() surfaces them)
        self.n_submitted = 0
        self.n_admitted = 0
        self.n_unadmitted = 0
        self.n_finished = 0
        # cache-aware admission: score queued requests (higher first, FIFO
        # tie-break) when more are queued than slots are free — the engine
        # plugs in expected prefix-cache hit length so requests that reuse
        # cached KV are admitted while their blocks are still resident
        self.admission_priority = None  # Optional[Callable[[Request], float]]
        # engine hook, called with (slot, SlotState) when a request leaves
        # its slot (prefix-cache block commit + refcount release)
        self.on_release = None

    # ------------------------------------------------------------------
    # Admission
    # ------------------------------------------------------------------

    def next_rid(self) -> int:
        """The rid the next submit() will be assigned (for auto-keying)."""
        return self._next_rid

    def submit(self, prompt: np.ndarray, n_tokens: int, temperature: float,
               key, extra=None) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.n_submitted += 1
        self.queue.append(Request(rid, np.asarray(prompt, np.int32),
                                  n_tokens, temperature, key, extra))
        return rid

    def admit(self) -> List[Tuple[int, SlotState]]:
        """Move queued requests into free slots. Submission order, unless
        ``admission_priority`` is set and the queue exceeds the free slots
        — then the highest-scoring requests win (FIFO tie-break) while the
        rest keep their relative order in the queue."""
        free = [s for s in range(self.n_slots) if self.slots[s] is None]
        if not free or not self.queue:
            return []
        if self.admission_priority is not None and len(self.queue) > len(free):
            reqs = list(self.queue)
            ranked = sorted(range(len(reqs)),
                            key=lambda i: (-self.admission_priority(reqs[i]),
                                           i))
            chosen = set(ranked[:len(free)])
            picked = [reqs[i] for i in sorted(chosen)]
            self.queue = collections.deque(
                reqs[i] for i in range(len(reqs)) if i not in chosen)
        else:
            picked = [self.queue.popleft()
                      for _ in range(min(len(free), len(self.queue)))]
        admitted = []
        for slot, req in zip(free, picked):
            st = SlotState(req)
            self.slots[slot] = st
            self.n_active += 1
            self.n_prefilling += 1
            self.n_admitted += 1
            admitted.append((slot, st))
        return admitted

    def unadmit(self, slot: int) -> None:
        """Undo an admission (before any token was generated): the request
        goes back to the front of the queue — the engine uses this when
        the block pool cannot cover the request yet. Rolls the admission
        gauges back exactly (pinned by the pool-starvation regression
        test against ``recount()``)."""
        st = self.slots[slot]
        assert st is not None and st.n_gen == 0
        self.slots[slot] = None
        self.n_active -= 1
        self.n_prefilling -= 1
        self.n_unadmitted += 1
        self.queue.appendleft(st.req)

    # ------------------------------------------------------------------
    # Token bookkeeping
    # ------------------------------------------------------------------

    def record_prefill(self, slot: int, tok: int) -> None:
        """The slot's prompt is fully in the cache and its first token is
        sampled: PREFILLING -> DECODING (or straight to finished)."""
        st = self.slots[slot]
        st.phase = DECODING
        self.n_prefilling -= 1
        if st.req.n_tokens == 0:  # degenerate: nothing to generate
            self._finish(slot)
            return
        st.n_gen = 1
        st.last_tok = int(tok)
        st.tokens.append(int(tok))
        if st.n_gen >= st.req.n_tokens:
            self._finish(slot)

    def needs_decode(self) -> bool:
        return any(st is not None and st.phase == DECODING
                   and st.n_gen < st.req.n_tokens
                   for st in self.slots)

    def decode_batch(self, dummy_key):
        """Per-slot arrays for one decode step over ALL slots (fixed jit
        shape). Free slots step on dummy values; their rows are overwritten
        wholesale at the next admission, so the garbage never escapes."""
        toks = np.zeros(self.n_slots, np.int32)
        idxs = np.zeros(self.n_slots, np.int32)
        steps = np.zeros(self.n_slots, np.int32)
        temps = np.zeros(self.n_slots, np.float32)
        keys = [dummy_key] * self.n_slots
        self._decoding = []
        for slot, st in enumerate(self.slots):
            if (st is None or st.phase == PREFILLING
                    or st.n_gen >= st.req.n_tokens):
                # PREFILLING slots decode nothing: their block tables still
                # point at the trash block, so the dummy row is harmless
                continue
            self._decoding.append(slot)
            toks[slot] = st.last_tok
            # the token being fed sits at position S0 + n_gen - 1
            idxs[slot] = len(st.req.prompt) + st.n_gen - 1
            steps[slot] = st.n_gen  # sampling fold-in index
            temps[slot] = st.req.temperature
            keys[slot] = st.req.key
        return toks, idxs, steps, temps, keys

    def decoding_slots(self) -> List[int]:
        """Slots the last ``decode_batch`` marked live — the rows whose
        sampled tokens ``record_decode`` will consume (the engine reads
        this to trace per-slot decode events and to build the fused mixed
        batch's per-row query counts)."""
        return list(self._decoding)

    def record_decode(self, toks: np.ndarray) -> None:
        for slot in self._decoding:
            st = self.slots[slot]
            st.n_gen += 1
            st.last_tok = int(toks[slot])
            st.tokens.append(int(toks[slot]))
            if st.n_gen >= st.req.n_tokens:
                self._finish(slot)
        self._decoding = []

    def record_spec(self, accepted: Dict[int, np.ndarray]) -> None:
        """Multi-token variant of :meth:`record_decode` for speculative
        steps: each slot the last ``decode_batch`` marked live appends its
        accepted tokens (longest matching draft prefix + the verify's
        bonus token — at least one). The engine's per-row draft budget
        guarantees acceptance never overruns the token budget; the assert
        pins that contract."""
        for slot in self._decoding:
            st = self.slots[slot]
            toks = accepted[slot]
            assert 1 <= len(toks) <= st.req.n_tokens - st.n_gen, (
                len(toks), st.n_gen, st.req.n_tokens)
            for t in toks:
                st.n_gen += 1
                st.last_tok = int(t)
                st.tokens.append(int(t))
            if st.n_gen >= st.req.n_tokens:
                self._finish(slot)
        self._decoding = []

    # ------------------------------------------------------------------
    # Completion
    # ------------------------------------------------------------------

    def _finish(self, slot: int) -> None:
        st = self.slots[slot]
        self._finished.append(Finished(
            st.req.rid, st.req.prompt,
            np.asarray(st.tokens, np.int32)))
        self.slots[slot] = None  # evict: slot is immediately reusable
        self.n_active -= 1
        self.n_finished += 1
        if self.on_release is not None:
            self.on_release(slot, st)

    def pop_finished(self) -> List[Finished]:
        out, self._finished = self._finished, []
        return out

    def pending(self) -> bool:
        return bool(self.queue) or any(st is not None for st in self.slots)

    # ------------------------------------------------------------------
    # Observability
    # ------------------------------------------------------------------

    def gauges(self) -> Dict[str, int]:
        """Incrementally maintained scheduler gauges + lifetime counters
        (surfaced by ``engine.metrics()['scheduler']``)."""
        return {"queue_depth": len(self.queue),
                "active_slots": self.n_active,
                "prefilling_slots": self.n_prefilling,
                "decoding_slots": self.n_active - self.n_prefilling,
                "free_slots": self.n_slots - self.n_active,
                "submitted": self.n_submitted,
                "admitted": self.n_admitted,
                "unadmitted": self.n_unadmitted,
                "finished": self.n_finished}

    def recount(self) -> Dict[str, int]:
        """Gauges recomputed from the SlotStates — the drift oracle the
        incremental ``gauges()`` counters are tested against."""
        active = [st for st in self.slots if st is not None]
        prefilling = sum(st.phase == PREFILLING for st in active)
        return {"queue_depth": len(self.queue),
                "active_slots": len(active),
                "prefilling_slots": prefilling,
                "decoding_slots": len(active) - prefilling,
                "free_slots": self.n_slots - len(active)}
