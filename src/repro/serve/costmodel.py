"""Analytical per-dispatch cost model for the serve engine.

The SWIS paper's headline numbers are *cost-model* numbers — cycles and
DRAM traffic as a function of bit-slice counts (§3.3, Table 4) — and the
serve stack's wall-clock observability cannot attribute a regression to
the quantity that actually explains bit-serial speedups: bytes moved.
This module closes that gap with pure shape-in/cost-out functions for
every launch kind the engine issues (decode batch, prefill, chunked
prefill, fused ``mixed_step``, speculative draft, ``verify_step``), each
returning a :class:`DispatchCost`:

* **flops** — GEMM work (2·K·C per token per weight), dense attention
  over the attended window (the launches compute masked full-length
  attention, so the window is the *capacity*, not the row's position),
  and the unembed GEMM over however many positions the launch unembeds.
* **hbm_bytes** — read + written: weights once per dispatch (packed
  leaves at their bit-plane footprint via
  :func:`repro.core.packing.compression_ratio`, honoring ``keep_slices``
  truncation — a truncated draft launch streams only the planes it
  reads), K/V read over the attended window and written per token,
  residual-stream activations, plus the gathered-K/V copy the reference
  paged-decode path materializes (:func:`decode_gathered_bytes`, pinned
  against the bench's measured ``decode_gathered_bytes_per_step``).
* **swis_cycles** — shift-pass cycles on a weight-stationary
  ``ARRAY_ROWS x ARRAY_COLS`` bit-serial array using the calibrated
  :mod:`repro.perfmodel.pe` constants: a packed GEMM retires one
  ``group_size`` MAC group per ``ceil(n_eff / shifts_per_cycle)`` passes
  (``n_eff`` = kept bit-slices), dense GEMMs run one MAC per PE per
  cycle. Attention (activation x activation, no stationary weights) is
  excluded by construction.

Approximations, stated once: MoE leaves count every expert (weights are
modeled as streamed per dispatch — an upper bound when routing is
sparse); chunked-prefill attention uses the working-tree length the
engine actually allocates; sub-byte tail effects of nibble-packed shift
metadata are folded into ``compression_ratio`` exactly as the paper's
§3.3 accounting does.

The engine wires a :class:`CostModel` (one per engine, built from the
live — possibly packed — parameter tree and the cache geometry) into
every dispatch site and records ``cost.flops`` / ``cost.hbm_bytes`` /
``cost.swis_cycles`` counters and per-kind histograms; see
docs/serving.md ("Observability") for the counter table.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.configs.base import ArchConfig
from repro.core.packing import compression_ratio
from repro.perfmodel.pe import PE_LIBRARY, PEConfig

# weight-leaf names the model's dense() path treats as GEMMs (mirrors
# repro.serve.quantized._eligible, minus the packability constraints —
# a GEMM too small to pack is still a GEMM)
GEMM_LEAF_NAMES = ("w", "wi", "wo", "wg", "shared_wi", "shared_wo",
                   "shared_wg")
_NON_GEMM_PATHS = ("embed", "router", "frontend")

# modeled systolic-array geometry: 8x8 PEs, the paper's §3.1 arrays
ARRAY_ROWS = 8
ARRAY_COLS = 8


@dataclasses.dataclass(frozen=True)
class GemmSpec:
    """One GEMM weight leaf: a trailing (k, c) matrix times ``stack``
    stacked copies (scanned layers and/or experts)."""

    k: int
    c: int
    stack: int = 1
    itemsize: int = 4  # dense storage bytes/element (float32 serving)
    packed: bool = False
    n_shifts: int = 0
    group_size: int = 4
    method: str = "swis"

    @property
    def macs(self) -> int:
        """MACs this weight contributes per processed token."""
        return self.stack * self.k * self.c

    def eff_shifts(self, keep_slices: Optional[int] = None) -> int:
        """Bit-slices a launch actually evaluates (keep_slices caps)."""
        if not self.packed:
            return 0
        if keep_slices is None:
            return self.n_shifts
        return max(1, min(keep_slices, self.n_shifts))

    def weight_bytes(self, keep_slices: Optional[int] = None) -> float:
        """HBM bytes one dispatch streams for this weight. Packed leaves
        read sign plane + kept mask planes + kept shift nibbles — exactly
        the §3.3 storage accounting, so ``compression_ratio`` of the kept
        slice count gives the footprint relative to 8-bit dense."""
        if not self.packed:
            return float(self.macs * self.itemsize)
        ratio = compression_ratio(self.group_size,
                                  self.eff_shifts(keep_slices), self.method)
        return self.macs / ratio  # 8-bit dense bytes / compression


@dataclasses.dataclass(frozen=True)
class CacheGeometry:
    """KV-cache shape facts the per-launch costs depend on."""

    n_layers: int
    n_kv_heads: int
    head_dim: int
    kv_itemsize: int
    attended_len: int  # positions a masked launch attends over (capacity)
    block_size: Optional[int] = None  # None: contiguous per-slot rows
    paged_impl: Optional[str] = None  # None | 'xla' | 'pallas'[_interpret]

    @property
    def kv_bytes_per_pos(self) -> int:
        """K + V bytes for one position, summed over layers."""
        return 2 * self.n_kv_heads * self.head_dim * self.kv_itemsize \
            * self.n_layers


@dataclasses.dataclass(frozen=True)
class DispatchCost:
    """Predicted cost of one model launch."""

    kind: str
    flops: float
    hbm_bytes: float  # read + written, gathered copy included
    swis_cycles: float
    gathered_bytes: float = 0.0  # materialized K/V copy (gather path)


def gemm_inventory(params: Any,
                   method: str = "swis") -> Tuple[List[GemmSpec], float]:
    """Walk a (possibly SWIS-packed) parameter tree.

    Returns ``(specs, other_bytes)``: the GEMM weight leaves the cost
    model accounts per token, and the total bytes of every other
    parameter (embed table, norms, routers, ...) — read once per
    dispatch but doing no per-token GEMM work (the unembed GEMM over the
    tied embed table is costed separately from the launch's unembedded
    position count)."""
    specs: List[GemmSpec] = []
    other = 0.0

    def walk(path, node):
        nonlocal other
        if isinstance(node, dict):
            if "mask_planes" in node:  # packed leaf (quantized.is_packed)
                sign, mask = node["sign_plane"], node["mask_planes"]
                k = int(sign.shape[-2]) * 32
                c = int(sign.shape[-1])
                stack = int(np.prod(mask.shape[:-3], dtype=np.int64))
                specs.append(GemmSpec(
                    k=k, c=c, stack=max(stack, 1), packed=True,
                    n_shifts=int(mask.shape[-3]),
                    group_size=k // int(node["shifts"].shape[-3]),
                    method=method))
                return
            for key, v in node.items():
                walk(path + (str(key),), v)
            return
        if not hasattr(node, "shape"):
            return
        nbytes = int(np.prod(node.shape, dtype=np.int64)) \
            * np.dtype(node.dtype).itemsize
        joined = "/".join(path)
        if (len(node.shape) >= 2 and path and path[-1] in GEMM_LEAF_NAMES
                and not any(p in joined for p in _NON_GEMM_PATHS)):
            specs.append(GemmSpec(
                k=int(node.shape[-2]), c=int(node.shape[-1]),
                stack=max(int(np.prod(node.shape[:-2], dtype=np.int64)), 1),
                itemsize=np.dtype(node.dtype).itemsize))
        else:
            other += nbytes

    walk((), params)
    return specs, other


def decode_gathered_bytes(geom: CacheGeometry, n_rows: int) -> float:
    """Bytes of gathered K/V one paged-decode launch materializes —
    the same quantity the bench measures as
    ``decode_gathered_bytes_per_step`` (serve_bench). The reference path
    rebuilds each row's contiguous arena view; the XLA scan fallback
    touches one block_size slab per scan step; the Pallas kernel indexes
    the arena in place and gathers nothing; contiguous (non-block)
    caches never gather."""
    if geom.block_size is None:
        return 0.0
    kv = 2 * n_rows * geom.n_kv_heads * geom.head_dim * geom.n_layers
    if geom.paged_impl is None:
        return float(kv * geom.attended_len * geom.kv_itemsize)
    if geom.paged_impl == "xla":
        return float(kv * geom.block_size * geom.kv_itemsize)
    return 0.0  # pallas / pallas_interpret: in-kernel indirection


def launch_cost(kind: str, cfg: ArchConfig, specs: List[GemmSpec],
                other_bytes: float, geom: CacheGeometry,
                pe: PEConfig, *, n_rows: int, s: int, kv_len: int,
                unembed_positions: int,
                keep_slices: Optional[int] = None,
                gather_rows: int = 0,
                act_itemsize: int = 4) -> DispatchCost:
    """Cost one model launch of ``n_rows`` rows x ``s`` token positions
    attending over ``kv_len`` cached positions and unembedding
    ``unembed_positions`` positions in total."""
    tokens = n_rows * s
    d_attn = cfg.n_heads * cfg.head_dim

    gemm_macs = sum(sp.macs for sp in specs)
    flops = 2.0 * tokens * gemm_macs
    flops += 4.0 * n_rows * s * kv_len * d_attn * cfg.n_layers
    flops += 2.0 * cfg.d_model * cfg.padded_vocab * unembed_positions

    weight = sum(sp.weight_bytes(keep_slices) for sp in specs) + other_bytes
    weight += 0.0  # unembed table already counted in other_bytes (tied)
    kv_read = float(n_rows) * kv_len * geom.kv_bytes_per_pos
    kv_write = float(tokens) * geom.kv_bytes_per_pos
    act = 2.0 * tokens * cfg.d_model * act_itemsize * cfg.n_layers
    gathered = decode_gathered_bytes(geom, gather_rows) if gather_rows \
        else 0.0
    hbm = weight + kv_read + kv_write + act + gathered

    array_macs = ARRAY_ROWS * ARRAY_COLS
    cycles = 0.0
    for sp in specs:
        if sp.packed:
            passes = pe.cycles_per_mac_group(sp.eff_shifts(keep_slices))
            cycles += tokens * sp.macs * passes / (array_macs * pe.group)
        else:
            cycles += tokens * sp.macs / array_macs
    cycles += cfg.d_model * cfg.padded_vocab * unembed_positions \
        / array_macs

    return DispatchCost(kind=kind, flops=flops, hbm_bytes=hbm,
                        swis_cycles=cycles, gathered_bytes=gathered)


class CostModel:
    """Per-dispatch cost predictions bound to one engine's geometry.

    Construct once (the inventory walk is O(n_leaves)); each ``decode``/
    ``prefill``/``chunk``/``mixed``/``draft``/``verify`` call is memoized
    by its launch shape, so the per-step recording overhead is a dict
    lookup for every steady-state shape."""

    def __init__(self, cfg: ArchConfig, params: Any, *, kv_itemsize: int,
                 attended_len: int, block_size: Optional[int] = None,
                 paged_impl: Optional[str] = None, method: str = "swis",
                 pe: Optional[PEConfig] = None):
        self.cfg = cfg
        self.specs, self.other_bytes = gemm_inventory(params, method)
        self.geom = CacheGeometry(
            n_layers=cfg.n_layers, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim, kv_itemsize=kv_itemsize,
            attended_len=attended_len, block_size=block_size,
            paged_impl=paged_impl)
        self.pe = pe or PE_LIBRARY["swis_ss"]
        self._memo: Dict[tuple, DispatchCost] = {}

    @classmethod
    def for_engine(cls, engine) -> "CostModel":
        """Build from a live ContinuousBatchingEngine: packed params,
        cache dtype/geometry, and paged backend as configured."""
        cache = engine.cache
        attended = cache.eff_len if cache.block_size else engine.max_len
        return cls(engine.cfg, engine.params,
                   kv_itemsize=np.dtype(cache.dtype).itemsize,
                   attended_len=attended, block_size=cache.block_size,
                   paged_impl=engine.paged_impl,
                   method=engine.cfg.quant.cfg.method)

    # -- launch kinds ----------------------------------------------------

    def _launch(self, kind: str, n_rows: int, s: int, kv_len: int,
                unembed_positions: int, keep_slices: Optional[int],
                gather_rows: int) -> DispatchCost:
        key = (kind, n_rows, s, kv_len, unembed_positions, keep_slices,
               gather_rows)
        cost = self._memo.get(key)
        if cost is None:
            cost = self._memo[key] = launch_cost(
                kind, self.cfg, self.specs, self.other_bytes, self.geom,
                self.pe, n_rows=n_rows, s=s, kv_len=kv_len,
                unembed_positions=unembed_positions,
                keep_slices=keep_slices, gather_rows=gather_rows)
        return cost

    def decode(self, n_rows: int) -> DispatchCost:
        """One batched S=1 decode step over ``n_rows`` slots."""
        return self._launch("decode", n_rows, 1, self.geom.attended_len,
                            n_rows, None, n_rows)

    def prefill(self, n_rows: int, s: int,
                kv_len: Optional[int] = None) -> DispatchCost:
        """One whole/suffix prefill group: ``n_rows`` rows of ``s``
        (padded) suffix tokens over a full-capacity working tree."""
        kv = self.geom.attended_len if kv_len is None else kv_len
        return self._launch("prefill", n_rows, s, kv, n_rows, None, 0)

    def chunk(self, n_rows: int, s: int, kv_len: int) -> DispatchCost:
        """One chunk-advance launch over the group's working tree
        (``kv_len`` = the tree length the engine allocated)."""
        return self._launch("chunk", n_rows, s, kv_len, n_rows, None, 0)

    def mixed(self, n_rows: int, s: int) -> DispatchCost:
        """One fused chunk+decode ``mixed_step``: every row computes
        ``s`` (masked) positions against the arena capacity."""
        return self._launch("mixed", n_rows, s, self.geom.attended_len,
                            n_rows, None, n_rows)

    def draft(self, n_rows: int,
              keep_slices: Optional[int] = None) -> DispatchCost:
        """One S=1 speculative draft launch with packed GEMMs truncated
        to ``keep_slices`` bit-planes (None: full precision)."""
        return self._launch("draft", n_rows, 1, self.geom.attended_len,
                            n_rows, keep_slices, n_rows)

    def verify(self, n_rows: int, s: int) -> DispatchCost:
        """One full-precision ``verify_step`` scoring all ``s`` positions
        per row (unembeds every position, unlike decode/prefill)."""
        return self._launch("verify", n_rows, s, self.geom.attended_len,
                            n_rows * s, None, n_rows)

    # -- static facts ----------------------------------------------------

    def summary(self) -> Dict[str, float]:
        """Model-static facts for the metrics snapshot: per-dispatch
        weight traffic (packed vs dense), per-token GEMM work, and the
        modeled compression."""
        dense = sum(sp.macs * (sp.itemsize if not sp.packed else 1)
                    for sp in self.specs) + self.other_bytes
        actual = sum(sp.weight_bytes() for sp in self.specs) \
            + self.other_bytes
        return {
            "n_gemm_leaves": len(self.specs),
            "n_packed_leaves": sum(sp.packed for sp in self.specs),
            "weight_bytes_per_dispatch": actual,
            "weight_bytes_dense8": float(dense),
            "gemm_flops_per_token":
                2.0 * sum(sp.macs for sp in self.specs),
            "swis_cycles_per_token": sum(
                (sp.macs * self.pe.cycles_per_mac_group(sp.n_shifts)
                 / (ARRAY_ROWS * ARRAY_COLS * self.pe.group)) if sp.packed
                else sp.macs / (ARRAY_ROWS * ARRAY_COLS)
                for sp in self.specs),
        }


def predicted_bandwidth(total_hbm_bytes: float,
                        total_step_seconds: float) -> float:
    """Model-implied HBM bandwidth (bytes/s) of a measured serving run:
    the bytes the cost model says the issued dispatches should move,
    over the wall time the step loop actually took. The engine exports
    this as the ``cost.hbm_bytes_per_s`` gauge (model-vs-measured
    utilization: compare against the substrate's peak)."""
    if total_step_seconds <= 0.0:
        return 0.0
    return total_hbm_bytes / total_step_seconds


def cycle_time_s(cycles: float, clock_hz: Optional[float] = None) -> float:
    """Seconds the modeled array needs for ``cycles`` shift-pass cycles
    (defaults to the paper's calibrated 650 MHz clock)."""
    from repro.perfmodel.pe import CLOCK_HZ

    return cycles / (clock_hz or CLOCK_HZ)
