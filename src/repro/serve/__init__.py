from repro.serve.engine import DecodeEngine
from repro.serve.quantized import pack_tree, packed_stats

__all__ = ["DecodeEngine", "pack_tree", "packed_stats"]
