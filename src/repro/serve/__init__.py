from repro.serve.config import EngineConfig, SamplingParams
from repro.serve.costmodel import CostModel, DispatchCost
from repro.serve.engine import ContinuousBatchingEngine, DecodeEngine
from repro.serve.kv_cache import SlotKVCache
from repro.serve.metrics import MetricsRegistry, format_report
from repro.serve.prefix_cache import BlockPool, RadixPrefixCache
from repro.serve.quantized import pack_tree, packed_stats
from repro.serve.scheduler import RequestScheduler
from repro.serve.trace import (RequestTracer, TraceWriter,
                               export_chrome_trace, read_jsonl)

__all__ = ["BlockPool", "ContinuousBatchingEngine", "CostModel",
           "DecodeEngine", "DispatchCost", "EngineConfig",
           "MetricsRegistry", "RadixPrefixCache", "RequestScheduler",
           "RequestTracer", "SamplingParams", "SlotKVCache", "TraceWriter",
           "export_chrome_trace", "format_report", "pack_tree",
           "packed_stats", "read_jsonl"]
