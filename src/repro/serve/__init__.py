from repro.serve.engine import ContinuousBatchingEngine, DecodeEngine
from repro.serve.kv_cache import SlotKVCache
from repro.serve.quantized import pack_tree, packed_stats
from repro.serve.scheduler import RequestScheduler

__all__ = ["ContinuousBatchingEngine", "DecodeEngine", "RequestScheduler",
           "SlotKVCache", "pack_tree", "packed_stats"]
