from repro.serve.engine import ContinuousBatchingEngine, DecodeEngine
from repro.serve.kv_cache import SlotKVCache
from repro.serve.prefix_cache import BlockPool, RadixPrefixCache
from repro.serve.quantized import pack_tree, packed_stats
from repro.serve.scheduler import RequestScheduler

__all__ = ["BlockPool", "ContinuousBatchingEngine", "DecodeEngine",
           "RadixPrefixCache", "RequestScheduler", "SlotKVCache",
           "pack_tree", "packed_stats"]
