from repro.serve.config import EngineConfig, SamplingParams
from repro.serve.engine import ContinuousBatchingEngine, DecodeEngine
from repro.serve.kv_cache import SlotKVCache
from repro.serve.metrics import MetricsRegistry, format_report
from repro.serve.prefix_cache import BlockPool, RadixPrefixCache
from repro.serve.quantized import pack_tree, packed_stats
from repro.serve.scheduler import RequestScheduler
from repro.serve.trace import RequestTracer, TraceWriter, read_jsonl

__all__ = ["BlockPool", "ContinuousBatchingEngine", "DecodeEngine",
           "EngineConfig", "MetricsRegistry", "RadixPrefixCache",
           "RequestScheduler", "RequestTracer", "SamplingParams",
           "SlotKVCache", "TraceWriter", "format_report", "pack_tree",
           "packed_stats", "read_jsonl"]
