"""Per-request lifecycle tracing for the continuous-batching engine.

Every request leaves a strictly ordered event stream:

    submit -> admit -> [prefix_hit] -> [unadmit -> admit ...]
           -> prefill_chunk[0..k] -> first_token -> decode_step* -> finish

Each :class:`TraceEvent` carries a monotonic timestamp
(``time.perf_counter``), the request id, and event-specific fields
(slot, matched prefix blocks, chunk index, decode step). The tracer
anchors one (wall-clock, monotonic) epoch pair at construction so JSONL
export carries real wall-clock timestamps while all derived intervals
(TTFT, TPOT, queue wait) are computed on the monotonic clock and can
never go negative under NTP steps.

Events live in a bounded in-memory ring (oldest dropped first, drop
count kept) so a long-lived server cannot grow without bound;
:class:`TraceWriter` streams events to a JSONL file whose lines
round-trip exactly (`json` shortest-repr floats), pinned by
``tests/test_trace.py``.

``serve_bench.py`` derives its reported TTFT percentiles from this layer
(``RequestTracer.summary``) instead of hand-rolled bookkeeping; the
schema table lives in ``docs/serving.md`` ("Observability").
"""
from __future__ import annotations

import collections
import dataclasses
import json
import time
from typing import Any, Dict, IO, Iterable, List, Optional

import numpy as np

# event kinds, in canonical lifecycle order (used by ordering checks)
SUBMIT = "submit"
ADMIT = "admit"
UNADMIT = "unadmit"
PREFIX_HIT = "prefix_hit"
PREFILL_CHUNK = "prefill_chunk"
FIRST_TOKEN = "first_token"
DECODE_STEP = "decode_step"
# speculative decode: one event per speculating slot per spec step, with
# proposed / accepted draft counts (decode_step events are still emitted
# per accepted token, so TTFT/TPOT derivations are spec-agnostic)
SPEC_ACCEPT = "spec_accept"
FINISH = "finish"

KINDS = (SUBMIT, ADMIT, UNADMIT, PREFIX_HIT, PREFILL_CHUNK, FIRST_TOKEN,
         DECODE_STEP, SPEC_ACCEPT, FINISH)


@dataclasses.dataclass
class SpanEvent:
    """One timed engine phase: a ``[ts, ts+dur)`` interval on the
    engine's step timeline, tagged with the step number it ran under.
    Spans live in their own bounded ring, separate from the request
    lifecycle ring — a chatty phase cannot evict lifecycle events."""

    name: str
    ts: float  # monotonic seconds (perf_counter), span start
    dur: float  # seconds
    step: int = 0


@dataclasses.dataclass
class TraceEvent:
    kind: str
    rid: int
    ts: float  # monotonic seconds (perf_counter)
    fields: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self, wall_offset: float = 0.0) -> Dict[str, Any]:
        d = {"kind": self.kind, "rid": self.rid, "ts": self.ts,
             "ts_wall": self.ts + wall_offset}
        d.update(self.fields)
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "TraceEvent":
        fields = {k: v for k, v in d.items()
                  if k not in ("kind", "rid", "ts", "ts_wall")}
        return cls(kind=d["kind"], rid=int(d["rid"]), ts=float(d["ts"]),
                   fields=fields)


class RequestTracer:
    """Bounded ring of :class:`TraceEvent` + derived per-request stats.

    ``enabled=False`` turns :meth:`event` into a single attribute check
    (no allocation, no clock read). The default capacity (65536) holds
    ~2k requests' full lifecycles at 24 generated tokens each.
    """

    def __init__(self, capacity: int = 65536, enabled: bool = True):
        self.enabled = enabled
        self.capacity = capacity
        self._ring: collections.deque = collections.deque(maxlen=capacity)
        self.dropped = 0
        # step-phase spans: separate bounded ring so phase spam (ten+
        # spans per step) can never evict request lifecycle events
        self._spans: collections.deque = collections.deque(maxlen=capacity)
        self.dropped_spans = 0
        self.current_step = 0  # engine sets this at each step() entry
        # wall-clock anchor: ts_wall = ts + wall_offset
        self._wall_offset = time.time() - time.perf_counter()

    # -- recording -------------------------------------------------------

    def event(self, kind: str, rid: int, ts: Optional[float] = None,
              **fields) -> None:
        if not self.enabled:
            return
        if len(self._ring) == self.capacity:
            self.dropped += 1
        self._ring.append(TraceEvent(
            kind, rid, time.perf_counter() if ts is None else ts, fields))

    def span(self, name: str, ts: float, dur: float) -> None:
        """Record one engine-phase span (monotonic start + duration)."""
        if not self.enabled:
            return
        if len(self._spans) == self.capacity:
            self.dropped_spans += 1
        self._spans.append(SpanEvent(name, ts, dur, self.current_step))

    def span_timer(self, name: str, hist=None) -> "_SpanTimer":
        """``with tracer.span_timer("decode_dispatch", hist):`` — on exit
        records a span AND observes the duration into ``hist`` (the
        phase histogram), so one clock read feeds both sinks."""
        return _SpanTimer(self, name, hist)

    def reset(self) -> None:
        self._ring.clear()
        self.dropped = 0
        self._spans.clear()
        self.dropped_spans = 0
        self.current_step = 0

    # -- access ----------------------------------------------------------

    def __len__(self) -> int:
        return len(self._ring)

    def events(self, rid: Optional[int] = None) -> List[TraceEvent]:
        if rid is None:
            return list(self._ring)
        return [e for e in self._ring if e.rid == rid]

    def spans(self, name: Optional[str] = None) -> List[SpanEvent]:
        if name is None:
            return list(self._spans)
        return [s for s in self._spans if s.name == name]

    @property
    def wall_offset(self) -> float:
        return self._wall_offset

    # -- derived per-request stats --------------------------------------

    def request_stats(self, rid: int) -> Dict[str, Any]:
        """Derived intervals for one request: queue wait (submit->admit),
        TTFT (submit->first_token), TPOT (mean decode-step delta), plus
        raw per-kind timestamps. Keys are absent when the ring no longer
        holds the events they need."""
        ts_of: Dict[str, float] = {}
        decode_ts: List[float] = []
        n_chunks = 0
        prefix_blocks = None
        for e in self._ring:
            if e.rid != rid:
                continue
            if e.kind == DECODE_STEP:
                decode_ts.append(e.ts)
            elif e.kind == PREFILL_CHUNK:
                n_chunks += 1
            elif e.kind == PREFIX_HIT:
                prefix_blocks = e.fields.get("blocks")
            if e.kind not in ts_of:  # first occurrence (re-admits later)
                ts_of[e.kind] = e.ts
        out: Dict[str, Any] = {"rid": rid, "n_decode_steps": len(decode_ts),
                               "n_prefill_chunks": n_chunks}
        if prefix_blocks is not None:
            out["prefix_hit_blocks"] = prefix_blocks
        if SUBMIT in ts_of and ADMIT in ts_of:
            out["queue_wait_s"] = ts_of[ADMIT] - ts_of[SUBMIT]
        if SUBMIT in ts_of and FIRST_TOKEN in ts_of:
            out["ttft_s"] = ts_of[FIRST_TOKEN] - ts_of[SUBMIT]
        if len(decode_ts) >= 1 and FIRST_TOKEN in ts_of:
            # time-per-output-token over the decode phase: first token is
            # t0, each decode step lands one more token
            out["tpot_s"] = ((decode_ts[-1] - ts_of[FIRST_TOKEN])
                             / len(decode_ts))
        return out

    def summary(self) -> Dict[str, Any]:
        """Aggregate derived stats over every rid present in the ring —
        TTFT / TPOT / queue-wait percentiles the bench reports."""
        rids = sorted({e.rid for e in self._ring})
        per = [self.request_stats(r) for r in rids]

        def pct(key):
            vals = [p[key] for p in per if key in p]
            if not vals:
                return {}
            a = np.asarray(vals)
            return {"p50": float(np.percentile(a, 50)),
                    "p95": float(np.percentile(a, 95)),
                    "mean": float(a.mean()), "n": len(vals)}

        return {"requests": len(rids), "events": len(self._ring),
                "dropped": self.dropped,
                "ttft_s": pct("ttft_s"), "tpot_s": pct("tpot_s"),
                "queue_wait_s": pct("queue_wait_s")}

    # -- export ----------------------------------------------------------

    def export_jsonl(self, path: str) -> int:
        """Dump the ring to a JSONL file (one event per line, wall-clock
        stamped). Returns the number of events written."""
        with TraceWriter(path, wall_offset=self._wall_offset) as w:
            for e in self._ring:
                w.write(e)
        return len(self._ring)

    def export_chrome_trace(self, path_or_file) -> int:
        """Write Chrome trace-event JSON (loads in Perfetto / chrome://
        tracing). Returns the number of trace events written. See
        :func:`export_chrome_trace`."""
        return export_chrome_trace(self, path_or_file)


class _SpanTimer:
    """Context manager: one ``perf_counter`` pair feeds both the phase
    histogram (seconds observed) and the tracer's span ring."""

    __slots__ = ("_tracer", "_name", "_hist", "_t0")

    def __init__(self, tracer: RequestTracer, name: str, hist=None):
        self._tracer = tracer
        self._name = name
        self._hist = hist

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        dur = time.perf_counter() - self._t0
        if self._hist is not None:
            self._hist.observe(dur)
        self._tracer.span(self._name, self._t0, dur)
        return False


def _us(ts: float, base: float) -> float:
    """Monotonic seconds -> trace microseconds relative to ``base``."""
    return round((ts - base) * 1e6, 3)


# fixed pids for the exported trace: engine phases vs request lifecycles
_PID_ENGINE = 1
_PID_REQUESTS = 2


def export_chrome_trace(tracer: RequestTracer, path_or_file) -> int:
    """Export the tracer's spans + lifecycle events as Chrome
    trace-event JSON (the format Perfetto and chrome://tracing load).

    Layout (see docs/serving.md "Observability" for the how-to):

    * **pid 1 "engine" / tid 0** — one ``X`` (complete) slice per
      recorded span. Phase spans (``admit``, ``decode_dispatch``, ...)
      nest under their enclosing ``step`` span by timestamp containment;
      ``args.step`` carries the engine step number.
    * **pid 2 "requests" / tid = rid** — per-request track: synthetic
      ``queued`` / ``prefill`` / ``decode`` interval slices derived from
      the lifecycle stream, every raw lifecycle event as an ``i``
      instant (fields in ``args``), and ``s``/``t``/``f`` flow arrows
      (id = rid) stitching the request's stages together so Perfetto
      draws the hand-off across tracks.

    Timestamps are microseconds relative to the earliest recorded event
    (Chrome traces care about relative placement, not epoch).
    """
    spans = list(tracer._spans)
    events = list(tracer._ring)
    ts0 = min([s.ts for s in spans] + [e.ts for e in events],
              default=0.0)

    out: List[Dict[str, Any]] = [
        {"ph": "M", "name": "process_name", "pid": _PID_ENGINE, "tid": 0,
         "ts": 0, "args": {"name": "engine"}},
        {"ph": "M", "name": "thread_name", "pid": _PID_ENGINE, "tid": 0,
         "ts": 0, "args": {"name": "engine.step"}},
        {"ph": "M", "name": "process_name", "pid": _PID_REQUESTS, "tid": 0,
         "ts": 0, "args": {"name": "requests"}},
    ]

    for s in spans:
        out.append({"ph": "X", "name": s.name, "cat": "phase",
                    "pid": _PID_ENGINE, "tid": 0, "ts": _us(s.ts, ts0),
                    "dur": round(s.dur * 1e6, 3),
                    "args": {"step": s.step}})

    by_rid: Dict[int, List[TraceEvent]] = {}
    for e in events:
        by_rid.setdefault(e.rid, []).append(e)

    for rid, evs in sorted(by_rid.items()):
        out.append({"ph": "M", "name": "thread_name", "pid": _PID_REQUESTS,
                    "tid": rid, "ts": 0, "args": {"name": f"req {rid}"}})
        first: Dict[str, float] = {}
        for e in evs:
            if e.kind not in first:
                first[e.kind] = e.ts
            out.append({"ph": "i", "name": e.kind, "cat": "lifecycle",
                        "pid": _PID_REQUESTS, "tid": rid,
                        "ts": _us(e.ts, ts0), "s": "t",
                        "args": dict(e.fields)})
        last_ts = evs[-1].ts
        # synthetic stage slices: queued -> prefill -> decode
        stages = []
        if SUBMIT in first and ADMIT in first:
            stages.append(("queued", first[SUBMIT], first[ADMIT]))
        if ADMIT in first and FIRST_TOKEN in first:
            stages.append(("prefill", first[ADMIT], first[FIRST_TOKEN]))
        if FIRST_TOKEN in first:
            end = first.get(FINISH, last_ts)
            stages.append(("decode", first[FIRST_TOKEN], end))
        for i, (name, t_lo, t_hi) in enumerate(stages):
            out.append({"ph": "X", "name": name, "cat": "request",
                        "pid": _PID_REQUESTS, "tid": rid,
                        "ts": _us(t_lo, ts0),
                        "dur": round(max(t_hi - t_lo, 0.0) * 1e6, 3),
                        "args": {"rid": rid}})
            # flow arrows thread the stages in lifecycle order
            ph = "s" if i == 0 else ("f" if i == len(stages) - 1 else "t")
            if len(stages) > 1:
                out.append({"ph": ph, "name": f"req{rid}",
                            "cat": "lifecycle", "id": rid,
                            "pid": _PID_REQUESTS, "tid": rid,
                            "ts": _us(t_lo, ts0)})

    doc = {"traceEvents": out, "displayTimeUnit": "ms"}
    if hasattr(path_or_file, "write"):
        json.dump(doc, path_or_file)
    else:
        with open(path_or_file, "w") as f:
            json.dump(doc, f)
    return len(out)


class TraceWriter:
    """Streaming JSONL sink for trace events.

    One JSON object per line; floats use python's shortest-repr encoding
    so a parse of the file reproduces every timestamp bit-exactly
    (round-trip pinned by ``tests/test_trace.py``). Usable as a context
    manager or with an already-open file object.
    """

    def __init__(self, path_or_file, wall_offset: float = 0.0):
        if hasattr(path_or_file, "write"):
            self._f: IO = path_or_file
            self._own = False
        else:
            self._f = open(path_or_file, "w")
            self._own = True
        self.wall_offset = wall_offset
        self.n_written = 0

    def write(self, event: TraceEvent) -> None:
        self._f.write(json.dumps(event.to_dict(self.wall_offset),
                                 separators=(",", ":")) + "\n")
        self.n_written += 1

    def close(self) -> None:
        if self._own:
            self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


def read_jsonl(path_or_lines) -> List[TraceEvent]:
    """Parse a TraceWriter JSONL file (or iterable of lines) back into
    events."""
    if isinstance(path_or_lines, str):
        with open(path_or_lines) as f:
            lines: Iterable[str] = f.readlines()
    else:
        lines = path_or_lines
    return [TraceEvent.from_dict(json.loads(ln))
            for ln in lines if ln.strip()]
