"""Lightweight metrics registry for the serve stack.

Zero-dependency (numpy-only) counters, gauges, and histograms behind a
single :class:`MetricsRegistry`, plus monotonic-clock timer contexts —
the measurement substrate `docs/serving.md` ("Observability") documents
and every serving perf PR is judged against.

Design constraints, in order:

* **Near-zero overhead when disabled.** A disabled registry hands out a
  shared no-op timer and every instrument mutation is a single attribute
  check away from returning. Nothing allocates per step.
* **Histogram percentiles must be trustworthy at bench scale.** Buckets
  alone interpolate; a bench gate wants the real p95. Histograms keep
  fixed log-spaced bucket counts (cheap, bounded, exportable) *and* a
  bounded ring of raw samples: ``percentile()`` is exact while the
  observation count fits the ring and falls back to log-linear bucket
  interpolation beyond it.
* **One snapshot API.** ``snapshot()`` returns a plain nested dict of
  python scalars/lists — JSON-ready, no live references, safe to diff
  across steps.

Timers use ``time.perf_counter`` (monotonic); wall-clock anchoring for
export lives in :mod:`repro.serve.trace`, not here.
"""
from __future__ import annotations

import collections
import math
import time
from typing import Dict, List, Optional

import numpy as np

# default histogram domain: 1us .. 1024s in 4 log-spaced buckets per
# decade — wide enough for a device-sync phase and a whole bench pass
_DEFAULT_LO = 1e-6
_DEFAULT_HI = 1024.0
_BUCKETS_PER_DECADE = 4
_SAMPLE_RING = 4096  # raw-sample ring: exact percentiles at bench scale


def log_buckets(lo: float = _DEFAULT_LO, hi: float = _DEFAULT_HI,
                per_decade: int = _BUCKETS_PER_DECADE) -> np.ndarray:
    """Fixed log-spaced bucket upper edges covering [lo, hi]."""
    n = int(math.ceil(math.log10(hi / lo) * per_decade)) + 1
    return lo * np.power(10.0, np.arange(n) / per_decade)


def cost_buckets() -> np.ndarray:
    """Bucket edges for cost-model histograms (FLOPs / bytes / cycles per
    dispatch): 1 .. 1e15 at 2 buckets per decade — coarse on purpose, the
    raw-sample ring carries the exact percentiles at bench scale."""
    return log_buckets(1.0, 1e15, per_decade=2)


class Counter:
    """Monotonically increasing count."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Point-in-time value (set wins; ``inc`` for deltas)."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Histogram:
    """Log-spaced-bucket histogram with a bounded raw-sample ring.

    ``observe`` is O(log n_buckets) (searchsorted) plus a deque append.
    ``percentile`` is exact while ``count <= ring capacity``; beyond that
    it interpolates log-linearly inside the bucket the rank falls in —
    the fixed edges mean the error is bounded by the bucket ratio
    (10^(1/per_decade), ~1.78x at the default 4/decade).
    """

    __slots__ = ("edges", "counts", "count", "total", "vmin", "vmax",
                 "_ring")

    def __init__(self, edges: Optional[np.ndarray] = None):
        self.edges = log_buckets() if edges is None else np.asarray(
            edges, np.float64)
        self.counts = np.zeros(len(self.edges) + 1, np.int64)
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self._ring: collections.deque = collections.deque(
            maxlen=_SAMPLE_RING)

    def observe(self, v: float) -> None:
        self.counts[int(np.searchsorted(self.edges, v))] += 1
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v
        self._ring.append(v)

    def percentile(self, q: float) -> float:
        """q in [0, 100]. Exact from the raw ring when nothing has been
        evicted from it; bucket-interpolated otherwise."""
        if self.count == 0:
            return 0.0
        if self.count <= self._ring.maxlen:
            return float(np.percentile(np.asarray(self._ring), q))
        rank = q / 100.0 * (self.count - 1)
        cum = np.cumsum(self.counts)
        b = int(np.searchsorted(cum, rank + 1))
        lo = self.edges[b - 1] if b > 0 else (
            self.vmin if self.vmin < self.edges[0] else self.edges[0] / 2)
        hi = self.edges[b] if b < len(self.edges) else self.vmax
        prev = cum[b - 1] if b > 0 else 0
        frac = (rank + 1 - prev) / max(self.counts[b], 1)
        # log-linear within the bucket (edges are log-spaced)
        lo = max(lo, 1e-12)
        return float(lo * (max(hi, lo) / lo) ** frac)

    def summary(self) -> Dict[str, float]:
        mean = self.total / self.count if self.count else 0.0
        return {"count": self.count, "sum": self.total, "mean": mean,
                "min": self.vmin if self.count else 0.0,
                "max": self.vmax if self.count else 0.0,
                "p50": self.percentile(50), "p95": self.percentile(95),
                "p99": self.percentile(99)}


class _Timer:
    """``with registry.timer("name"):`` — observes elapsed seconds."""

    __slots__ = ("_hist", "_t0")

    def __init__(self, hist: Histogram):
        self._hist = hist

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self._hist.observe(time.perf_counter() - self._t0)
        return False


class _NullTimer:
    """Shared no-op context: the disabled-registry fast path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_TIMER = _NullTimer()
_NULL_COUNTER = Counter()  # sink for disabled-registry mutations
_NULL_GAUGE = Gauge()


class MetricsRegistry:
    """Named counters/gauges/histograms with one ``snapshot()``.

    Instruments are created on first use and live for the registry's
    lifetime (``reset()`` zeroes them in place, so held references stay
    valid — the engine keeps phase timers across ``engine.reset()``).
    When ``enabled=False`` every accessor returns a shared no-op/sink
    instrument and ``timer()`` returns a shared null context — the hot
    path pays one attribute check, no allocation, no clock read.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._hists: Dict[str, Histogram] = {}

    # -- instruments -----------------------------------------------------

    def counter(self, name: str) -> Counter:
        if not self.enabled:
            return _NULL_COUNTER
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        if not self.enabled:
            return _NULL_GAUGE
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge()
        return g

    def histogram(self, name: str,
                  edges: Optional[np.ndarray] = None) -> Histogram:
        if not self.enabled:
            return _DISABLED_HIST
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = Histogram(edges)
        return h

    def timer(self, name: str):
        if not self.enabled:
            return _NULL_TIMER
        return _Timer(self.histogram(name))

    def observe(self, name: str, v: float) -> None:
        if self.enabled:
            self.histogram(name).observe(v)

    # -- lifecycle -------------------------------------------------------

    def reset(self) -> None:
        """Zero every instrument in place (references stay valid)."""
        for c in self._counters.values():
            c.value = 0
        for g in self._gauges.values():
            g.value = 0.0
        for h in self._hists.values():
            h.counts[:] = 0
            h.count = 0
            h.total = 0.0
            h.vmin = math.inf
            h.vmax = -math.inf
            h._ring.clear()

    def snapshot(self) -> Dict[str, Dict]:
        """Plain nested dict of python scalars — JSON-ready, no live
        references. Histograms export their summary plus non-empty
        bucket (upper-edge, count) pairs."""
        out: Dict[str, Dict] = {
            "counters": {k: c.value for k, c in self._counters.items()},
            "gauges": {k: g.value for k, g in self._gauges.items()},
            "histograms": {},
        }
        for k, h in self._hists.items():
            s = h.summary()
            nz = np.nonzero(h.counts)[0]
            s["buckets"] = [
                [float(h.edges[i]) if i < len(h.edges) else math.inf,
                 int(h.counts[i])] for i in nz]
            out["histograms"][k] = s
        return out


class _DisabledHistogram(Histogram):
    """Sink histogram handed out by a disabled registry."""

    __slots__ = ()

    def observe(self, v: float) -> None:  # drop
        return


_DISABLED_HIST = _DisabledHistogram()


def _hist_unit(name: str):
    """(scale, suffix, format) for a histogram by name convention:
    ``*_s`` seconds → ms, ``*_bytes`` → MiB, anything else (FLOPs,
    cycles) raw with a compact general format."""
    if name.endswith("_s"):
        return 1e3, "ms", ".3f"
    if name.endswith("_bytes"):
        return 1.0 / 2**20, "MiB", ".3f"
    return 1.0, "", ".4g"


def format_report(snapshot: Dict[str, Dict], title: str = "metrics") -> str:
    """Human-readable multi-line report of a ``snapshot()`` dict —
    used by ``launch/serve.py`` periodic reports and the quickstart
    example. Each histogram is scaled by its name's unit convention
    (``_s`` → ms, ``_bytes`` → MiB, else raw), so step-phase timings and
    cost-model byte/FLOP/cycle histograms render side by side without
    mislabeling."""
    lines: List[str] = [f"== {title} =="]
    if snapshot.get("counters"):
        lines.append("  counters: " + "  ".join(
            f"{k}={v:g}" if isinstance(v, float) else f"{k}={v}"
            for k, v in sorted(snapshot["counters"].items())))
    if snapshot.get("gauges"):
        lines.append("  gauges:   " + "  ".join(
            f"{k}={v:g}" for k, v in sorted(snapshot["gauges"].items())))
    for k in sorted(snapshot.get("histograms", {})):
        s = snapshot["histograms"][k]
        if not s["count"]:
            continue
        scale, unit, fmt = _hist_unit(k)
        lines.append(
            f"  {k}: n={s['count']} p50={s['p50'] * scale:{fmt}}{unit} "
            f"p95={s['p95'] * scale:{fmt}}{unit} "
            f"max={s['max'] * scale:{fmt}}{unit}")
    return "\n".join(lines)
